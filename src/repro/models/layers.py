"""Shared neural-net layers for the model zoo (pure JAX, no flax).

Conventions:
  * params are nested dicts of jnp arrays, built from ParamDef trees;
  * every forward fn takes (p, cfg, run, ...) where p is the param subtree;
  * activations carry logical sharding constraints via sharding.constrain;
  * attention dispatches between a heads-sharded flash path and a
    kv-materialized q-chunked path for archs whose head count does not
    divide the model axis (qwen1.5-32b 40H, qwen1.5-4b 20H, whisper 6H).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.kernels import ops
from repro.kernels.ref import NEG_INF
from repro.models.params import pdef
from repro.sharding import constrain, current_rules

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------


def norm_defs(L: int, d: int):
    return pdef((L, d) if L else (d,),
                ("layers", None) if L else (None,), init="ones")


def attention_defs(cfg: ModelConfig, L: int, *, cross: bool = False):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    lead = (L,) if L else ()
    ll = ("layers",) if L else ()
    out: Params = {
        "wq": pdef(lead + (d, qd), ll + ("embed", "qkv"), init="scaled"),
        "wk": pdef(lead + (d, kvd), ll + ("embed", "qkv"), init="scaled"),
        "wv": pdef(lead + (d, kvd), ll + ("embed", "qkv"), init="scaled"),
        "wo": pdef(lead + (qd, d), ll + ("qkv", "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        out["bq"] = pdef(lead + (qd,), ll + ("qkv",), init="zeros")
        out["bk"] = pdef(lead + (kvd,), ll + ("qkv",), init="zeros")
        out["bv"] = pdef(lead + (kvd,), ll + ("qkv",), init="zeros")
    return out


def mlp_defs(cfg: ModelConfig, L: int, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    lead = (L,) if L else ()
    ll = ("layers",) if L else ()
    out: Params = {
        "w_up": pdef(lead + (d, f), ll + ("embed", "ffn"), init="scaled"),
        "w_down": pdef(lead + (f, d), ll + ("ffn", "embed"), init="scaled"),
    }
    if cfg.gated_mlp:
        out["w_gate"] = pdef(lead + (d, f), ll + ("embed", "ffn"),
                             init="scaled")
    if cfg.mlp_bias:
        out["b_up"] = pdef(lead + (f,), ll + ("ffn",), init="zeros")
        out["b_down"] = pdef(lead + (d,), ll + (None,), init="zeros")
    return out


def moe_defs(cfg: ModelConfig, L: int):
    """Expert weights carry BOTH "expert" and "ffn" logical tags; the
    rules dedup shards on whichever divides: qwen3-moe (128e) -> EP on the
    expert dim, mixtral (8e < 16) -> TP on the per-expert ffn dim."""
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": pdef((L, d, E), ("layers", "embed", None),
                       init="scaled", dtype=jnp.float32),
        "w_gate": pdef((L, E, d, f), ("layers", "expert", "embed", "ffn"),
                       init="scaled"),
        "w_up": pdef((L, E, d, f), ("layers", "expert", "embed", "ffn"),
                     init="scaled"),
        "w_down": pdef((L, E, f, d), ("layers", "expert", "ffn", "embed"),
                       init="scaled"),
    }


# ---------------------------------------------------------------------------
# Norm / activations / RoPE
# ---------------------------------------------------------------------------


def rmsnorm(p, x, cfg: ModelConfig, run: RunConfig):
    return ops.rmsnorm(x, p, eps=cfg.norm_eps, use_pallas=run.use_pallas)


def act_fn(name: str):
    return jax.nn.silu if name == "silu" else jax.nn.gelu


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (S,) or broadcastable."""
    if theta <= 0:
        return x
    D = x.shape[-1]
    half = D // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d: int) -> jax.Array:
    """positions: (S,) (possibly traced). Returns (S, d)."""
    pos = positions.astype(jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# KV cache (bf16 or int8-quantized)
# ---------------------------------------------------------------------------


def kv_cache_defs(cfg: ModelConfig, L: int, batch: int, max_len: int):
    """Abstract structure for one stack of per-layer KV caches."""
    Hkv, Dh = cfg.num_kv_heads, cfg.head_dim
    shp = (L, batch, max_len, Hkv, Dh)
    logical = ("layers", "batch", "kv_seq", "heads", None)
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": pdef(shp, logical, init="zeros", dtype=jnp.int8),
            "v": pdef(shp, logical, init="zeros", dtype=jnp.int8),
            "k_scale": pdef(shp[:-1], logical[:-1], init="zeros",
                            dtype=jnp.float32),
            "v_scale": pdef(shp[:-1], logical[:-1], init="zeros",
                            dtype=jnp.float32),
        }
    return {
        "k": pdef(shp, logical, init="zeros", dtype=jnp.bfloat16),
        "v": pdef(shp, logical, init="zeros", dtype=jnp.bfloat16),
    }


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-(token, head) symmetric int8 quantization. x: (..., Dh)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def cache_update(cache: Params, layer_k: jax.Array, layer_v: jax.Array,
                 pos, cfg: ModelConfig) -> Params:
    """Write new K/V (B, S_new, Hkv, Dh) into a single-layer cache at pos."""
    out = dict(cache)
    if cfg.kv_cache_dtype == "int8":
        qk, sk = quantize_kv(layer_k)
        qv, sv = quantize_kv(layer_v)
        out["k"] = lax.dynamic_update_slice_in_dim(cache["k"], qk, pos, 1)
        out["v"] = lax.dynamic_update_slice_in_dim(cache["v"], qv, pos, 1)
        out["k_scale"] = lax.dynamic_update_slice_in_dim(
            cache["k_scale"], sk, pos, 1)
        out["v_scale"] = lax.dynamic_update_slice_in_dim(
            cache["v_scale"], sv, pos, 1)
    else:
        out["k"] = lax.dynamic_update_slice_in_dim(
            cache["k"], layer_k.astype(cache["k"].dtype), pos, 1)
        out["v"] = lax.dynamic_update_slice_in_dim(
            cache["v"], layer_v.astype(cache["v"].dtype), pos, 1)
    return out


def cache_read(cache: Params, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    if cfg.kv_cache_dtype == "int8":
        return (dequantize_kv(cache["k"], cache["k_scale"]),
                dequantize_kv(cache["v"], cache["v_scale"]))
    return cache["k"], cache["v"]


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _heads_shardable(n_heads: int) -> bool:
    r = current_rules()
    if r is None:
        return True
    return r.resolve_dim("heads", n_heads) is not None


def _attention_kvseq(q, k, v, *, causal, q_offset, kv_len, sliding_window,
                     block_q: int = 1024, scale=None):
    """Fallback attention for non-divisible head counts: KV sequence is
    sharded on the model axis; scores materialize per q-chunk and the
    softmax reduction crosses shards (flash-decoding layout).
    """
    B, Sq, Hq, Dh = q.shape
    Sk = k.shape[1]
    G = Hq // max(k.shape[2], 1)
    scale = scale if scale is not None else Dh ** -0.5
    k = constrain(k, "batch", "kv_seq", None, None)
    v = constrain(v, "batch", "kv_seq", None, None)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    k_pos = jnp.arange(Sk)
    valid = Sk if kv_len is None else kv_len

    block_q = min(block_q, Sq)
    pad_q = (-Sq) % block_q
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    nq = qp.shape[1] // block_q
    qb = qp.reshape(B, nq, block_q, Hq, Dh).transpose(1, 0, 2, 3, 4)

    def one_block(args):
        qblk, i = args
        qf = (qblk.astype(jnp.float32) * scale).reshape(
            B, block_q, k.shape[2], G, Dh)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kf)
        s = constrain(s, "batch", None, None, None, "kv_seq")
        q_pos = q_offset + i * block_q + jnp.arange(block_q)
        mask = k_pos[None, :] < valid
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if sliding_window:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - sliding_window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bqhgk,bkhd->bqhgd", p, vf)
        return o.reshape(B, block_q, Hq, Dh)

    if nq == 1:
        out = one_block((qb[0], 0))[None]
    else:
        out = lax.map(one_block, (qb, jnp.arange(nq)))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, -1, Hq, Dh)[:, :Sq]
    return out.astype(q.dtype)


def attention(p: Params, cfg: ModelConfig, run: RunConfig, x: jax.Array,
              *, positions: jax.Array, causal: bool = True,
              cache: Optional[Params] = None, cache_pos=None,
              kv_len=None, xkv: Optional[jax.Array] = None,
              cache_read_only: bool = False,
              use_rope: bool = True) -> Tuple[jax.Array, Optional[Params]]:
    """General GQA attention with optional KV cache and cross-attention.

    x: (B, S, d_model). xkv: encoder output for cross-attention.
    cache: single-layer cache dict (already sliced out of the stack).
    cache_pos: scalar write offset into the cache.
    cache_read_only: cross-attention decode — use cached K/V, no update.
    Returns (out, updated_cache).
    """
    B, S, _ = x.shape
    Hq, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    src = x if xkv is None else xkv

    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = constrain(q, "batch", None, "qkv")
    q = q.reshape(B, S, Hq, Dh)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)

    if cache_read_only:
        # cross-attention during decode: KV precomputed at prefill
        k, v = cache_read(cache, cfg)
        new_cache = cache
    else:
        k = src @ p["wk"]
        v = src @ p["wv"]
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = constrain(k, "batch", None, "qkv").reshape(B, -1, Hkv, Dh)
        v = constrain(v, "batch", None, "qkv").reshape(B, -1, Hkv, Dh)
        if use_rope and xkv is None:
            k = rope(k, positions, cfg.rope_theta)
        new_cache = cache
        if cache is not None:
            new_cache = cache_update(cache, k, v, cache_pos, cfg)
            k, v = cache_read(new_cache, cfg)

    q_offset = positions[0] if positions.ndim else positions
    heads_ok = _heads_shardable(Hq)
    if S == 1:
        # decode: flash-decoding layout — KV sequence sharded on the model
        # axis, partial softmax reduced across shards by GSPMD.
        out = _attention_kvseq(
            q, k, v, causal=causal, q_offset=q_offset,
            kv_len=kv_len, sliding_window=cfg.sliding_window)
    elif heads_ok:
        # TP over heads. For GQA, K/V are repeated up to Hq *after* the
        # head constraint so every intermediate carries a clean 16-way
        # head sharding (the grouped (Hkv, G) layout cannot express a
        # single mesh axis and triggers involuntary SPMD remats).
        q = constrain(q, "batch", None, "heads", None)
        if k.shape[2] != Hq:
            G = Hq // k.shape[2]
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
        k = constrain(k, "batch", None, "heads", None)
        v = constrain(v, "batch", None, "heads", None)
        out = ops.flash_attention(
            q, k, v, causal=causal, q_offset=q_offset,
            kv_len=kv_len, sliding_window=cfg.sliding_window,
            block_k=run.attn_block_k, use_pallas=run.use_pallas,
            custom_vjp=run.flash_custom_vjp,
            carry_constrain=lambda t: constrain(
                t, *(("batch", None, "heads") + (None,) * (t.ndim - 3))))
    else:
        # head count does not divide the model axis (qwen1.5-32b 40H,
        # qwen1.5-4b 20H, whisper 6H): shard the QUERY sequence instead
        # (sequence-parallel attention); K/V replicated per layer.
        q = constrain(q, "batch", "q_seq", None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
        out = ops.flash_attention(
            q, k, v, causal=causal, q_offset=q_offset,
            kv_len=kv_len, sliding_window=cfg.sliding_window,
            block_k=run.attn_block_k, use_pallas=run.use_pallas,
            custom_vjp=run.flash_custom_vjp,
            carry_constrain=lambda t: constrain(
                t, *(("batch", "q_seq") + (None,) * (t.ndim - 2))))

    out = out.reshape(B, S, Hq * Dh)
    out = constrain(out, "batch", None, "qkv")
    y = out @ p["wo"]
    return constrain(y, "batch", None, None), new_cache


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------


def mlp(p: Params, cfg: ModelConfig, run: RunConfig, x: jax.Array,
        act: Optional[str] = None) -> jax.Array:
    a = act_fn(act or cfg.act)
    up = x @ p["w_up"]
    if "b_up" in p:
        up = up + p["b_up"]
    up = constrain(up, "batch", None, "ffn")
    if "w_gate" in p:
        gate = constrain(x @ p["w_gate"], "batch", None, "ffn")
        h = a(gate) * up
    else:
        h = a(up)
    y = h @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return constrain(y, "batch", None, None)


def moe_block(p: Params, cfg: ModelConfig, run: RunConfig,
              x: jax.Array) -> jax.Array:
    """Top-k MoE dispatch. Two implementations:

    shardmap (default, §Perf winner): explicit expert parallelism.  The
      batch is sharded over (pod, data) and replicated over model, so each
      model column already holds every token — no all-to-all is needed.
      Each device routes its local tokens, runs ONLY its local experts
      (qwen3: 8/128 experts; mixtral: all 8 experts on a 1/16 ffn slice),
      and one psum over the model axis combines the (disjoint or
      f-partial) contributions.  Collectives: exactly one psum of the
      activation per layer.

    gspmd (baseline): per-row sort-based dispatch under vmap, sharding
      left to the compiler — measured to produce TB-scale all-reduce /
      all-to-all chatter from the scatter/gather ops (EXPERIMENTS.md
      §Perf iterations 1-2).
    """
    r = current_rules()
    if (run.moe_impl == "shardmap" and r is not None
            and "model" in r.mesh.shape and x.shape[1] > 1):
        # decode (S=1) stays on the gspmd path: with ~8 local tokens the
        # shard_map dispatch overhead is unamortized (§Perf, measured
        # +13% on qwen3/mixtral decode_32k).
        return _moe_block_shardmap(p, cfg, run, x)
    return _moe_block_gspmd(p, cfg, run, x)


def _moe_block_gspmd(p: Params, cfg: ModelConfig, run: RunConfig,
                     x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    C = max(int(math.ceil(S * K / E * cfg.moe_capacity_factor)), 1)
    a = act_fn(cfg.act)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(gates, K)  # (B, S, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # NOTE (§Perf iter 1, kept for the record): constraining the expert
    # weights d-replicated here kills the TB-scale activation all-reduces
    # but makes GSPMD drop its d-contraction compute split (9x flops) and
    # regresses decode. Net-negative -> reverted; train/prefill use the
    # shard_map path instead.
    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]

    def route_row(xr, er, wr):
        # xr: (S, d), er/wr: (S, K)
        flat_e = er.reshape(-1)                       # (S*K,)
        order = jnp.argsort(flat_e, stable=True)
        tok = order // K                              # source token
        se = flat_e[order]
        start = jnp.searchsorted(se, jnp.arange(E))   # first slot per expert
        pos = jnp.arange(S * K) - start[se]
        keep = pos < C
        slot = jnp.clip(se * C + pos, 0, E * C - 1)
        xe = jnp.zeros((E * C, d), x.dtype)
        xe = xe.at[slot].add(jnp.where(keep[:, None], xr[tok], 0))
        xe = xe.reshape(E, C, d)
        # expert FFN — sharding propagates from the weights: EP on the
        # expert dim (qwen3-moe) or TP on the per-expert ffn dim (mixtral);
        # see moe_defs. (No explicit constraint: this code runs under vmap.)
        g = jnp.einsum("ecd,edf->ecf", xe, w_gate)
        u = jnp.einsum("ecd,edf->ecf", xe, w_up)
        h = a(g) * u
        ye = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E * C, d)
        # combine
        contrib = ye[slot] * jnp.where(keep, wr.reshape(-1)[order], 0.0
                                       )[:, None].astype(ye.dtype)
        y = jnp.zeros((S, d), ye.dtype).at[tok].add(contrib)
        return y

    y = jax.vmap(route_row)(x, top_e, top_w)
    return constrain(y.astype(x.dtype), "batch", None, None)


def _moe_block_shardmap(p: Params, cfg: ModelConfig, run: RunConfig,
                        x: jax.Array) -> jax.Array:
    from jax.sharding import PartitionSpec as PS

    r = current_rules()
    mesh = r.mesh
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    d, f = cfg.d_model, cfg.d_ff
    a = act_fn(cfg.act)
    n_model = mesh.shape.get("model", 1)
    e_sharded = E % n_model == 0 and n_model > 1
    E_loc = E // n_model if e_sharded else E

    x_spec = r.spec(("batch", None, None), x.shape)
    if e_sharded:
        w_in_spec = PS("model", None, None)       # (E_loc, d, f) local
        w_out_spec = PS("model", None, None)      # (E_loc, f, d) local
    else:
        w_in_spec = PS(None, None, "model")       # (E, d, f_loc) local
        w_out_spec = PS(None, "model", None)      # (E, f_loc, d) local

    def local_moe(xl, router, wg, wu, wd):
        B_l, S, _ = xl.shape
        T = B_l * S
        C = max(int(math.ceil(T * K / E * cfg.moe_capacity_factor)), 1)
        xt = xl.reshape(T, d)
        logits = xt.astype(jnp.float32) @ router          # (T, E)
        gates = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = lax.top_k(gates, K)                # (T, K)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        flat_e = top_e.reshape(-1)                        # (T*K,) global ids
        order = jnp.argsort(flat_e, stable=True)
        tok = order // K
        se = flat_e[order]
        base = (lax.axis_index("model") * E_loc) if e_sharded else 0
        le = se - base                                    # local expert id
        local = (le >= 0) & (le < E_loc)
        start = jnp.searchsorted(se, base + jnp.arange(E_loc))
        pos = jnp.arange(T * K) - start[jnp.clip(le, 0, E_loc - 1)]
        keep = local & (pos < C)
        slot = jnp.clip(le * C + pos, 0, E_loc * C - 1)

        xe = jnp.zeros((E_loc * C, d), xt.dtype)
        xe = xe.at[slot].add(jnp.where(keep[:, None], xt[tok], 0))
        xe = xe.reshape(E_loc, C, d)
        g = jnp.einsum("ecd,edf->ecf", xe, wg)
        u = jnp.einsum("ecd,edf->ecf", xe, wu)
        ye = jnp.einsum("ecf,efd->ecd", a(g) * u, wd).reshape(E_loc * C, d)

        wsel = jnp.where(keep, top_w.reshape(-1)[order], 0.0)
        contrib = ye[slot] * wsel[:, None].astype(ye.dtype)
        y = jnp.zeros((T, d), ye.dtype).at[tok].add(contrib)
        # disjoint expert contributions (EP) or f-slice partials (TP):
        # one psum over the model axis combines either way.
        y = lax.psum(y, "model")
        return y.reshape(B_l, S, d).astype(xl.dtype)

    fn = jax.shard_map(
        local_moe, mesh=mesh,
        in_specs=(x_spec, PS(None, None), w_in_spec, w_in_spec, w_out_spec),
        out_specs=x_spec,
        check_vma=False,
    )
    return fn(x, p["router"].astype(jnp.float32), p["w_gate"], p["w_up"],
              p["w_down"])


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_defs(cfg: ModelConfig):
    out = {"tok": pdef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        out["lm_head"] = pdef((cfg.vocab_size, cfg.d_model),
                              ("vocab", "embed"), init="scaled")
    return out


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    y = jnp.take(p["tok"], tokens, axis=0)
    return constrain(y, "batch", None, None)


def lm_head_weight(p: Params, cfg: ModelConfig) -> jax.Array:
    return p["tok"] if cfg.tie_embeddings else p["lm_head"]


def logits_out(p: Params, cfg: ModelConfig, run: RunConfig,
               x: jax.Array) -> jax.Array:
    w = lm_head_weight(p, cfg)
    y = jnp.einsum("bsd,vd->bsv", x, w)
    if run.logits_in_fp32:
        y = y.astype(jnp.float32)
    return constrain(y, "batch", None, "vocab")
