"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv mel frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, F, d_model) from ``input_specs()``.
Positions are sinusoidal (the learned 448-position table of the original
checkpoint does not extend to the assigned 32k decode shapes; adaptation
noted in DESIGN.md).  Decode keeps a causal self-attention cache plus
cross-attention K/V computed once at prefill.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L
from repro.sharding import constrain

Params = Dict[str, Any]


def param_defs(cfg: ModelConfig) -> Params:
    ne, nd = cfg.encoder_layers, cfg.num_layers
    return {
        "embed": L.embed_defs(cfg),
        "enc_blocks": {
            "ln1": L.norm_defs(ne, cfg.d_model),
            "attn": L.attention_defs(cfg, ne),
            "ln2": L.norm_defs(ne, cfg.d_model),
            "mlp": L.mlp_defs(cfg, ne),
        },
        "enc_ln_f": L.norm_defs(0, cfg.d_model),
        "dec_blocks": {
            "ln1": L.norm_defs(nd, cfg.d_model),
            "self_attn": L.attention_defs(cfg, nd),
            "ln_x": L.norm_defs(nd, cfg.d_model),
            "cross_attn": L.attention_defs(cfg, nd),
            "ln2": L.norm_defs(nd, cfg.d_model),
            "mlp": L.mlp_defs(cfg, nd),
        },
        "dec_ln_f": L.norm_defs(0, cfg.d_model),
    }


def encode(params: Params, cfg: ModelConfig, run: RunConfig,
           frames: jax.Array) -> jax.Array:
    """frames: (B, F, d_model) precomputed embeddings (stub frontend)."""
    positions = jnp.arange(frames.shape[1])
    x = frames + L.sinusoidal_positions(positions, cfg.d_model
                                        ).astype(frames.dtype)[None]
    x = constrain(x, "batch", None, None)

    def blk(p, hh):
        a = L.rmsnorm(p["ln1"], hh, cfg, run)
        a, _ = L.attention(p["attn"], cfg, run, a, positions=positions,
                           causal=False, use_rope=False)
        hh = hh + a
        m = L.rmsnorm(p["ln2"], hh, cfg, run)
        return hh + L.mlp(p["mlp"], cfg, run, m)

    fn = jax.checkpoint(blk) if run.remat != "none" else blk

    if run.scan_layers:
        x, _ = lax.scan(lambda c, p_l: (fn(p_l, c), None),
                        x, params["enc_blocks"])
    else:
        for i in range(cfg.encoder_layers):
            p_l = jax.tree.map(lambda a: a[i], params["enc_blocks"])
            x = fn(p_l, x)
    return L.rmsnorm(params["enc_ln_f"], x, cfg, run)


def _dec_block(p, cfg, run, x, positions, enc_out, self_c, cross_c,
               cache_pos, kv_len):
    h = L.rmsnorm(p["ln1"], x, cfg, run)
    h, new_self = L.attention(p["self_attn"], cfg, run, h,
                              positions=positions, cache=self_c,
                              cache_pos=cache_pos, kv_len=kv_len,
                              use_rope=False)
    x = x + h
    h = L.rmsnorm(p["ln_x"], x, cfg, run)
    # cross-attn: enc_out given at prefill/train; cached K/V at decode
    h, new_cross = L.attention(p["cross_attn"], cfg, run, h,
                               positions=positions, causal=False,
                               xkv=enc_out, cache=cross_c, cache_pos=0,
                               cache_read_only=enc_out is None,
                               use_rope=False)
    x = x + h
    h = L.rmsnorm(p["ln2"], x, cfg, run)
    return x + L.mlp(p["mlp"], cfg, run, h), new_self, new_cross


def _run_decoder(params, cfg, run, tokens, enc_out, pos0, self_cache=None,
                 cross_cache=None, cache_pos=None, kv_len=None):
    x = L.embed(params["embed"], tokens)
    S = x.shape[1]
    positions = pos0 + jnp.arange(S)
    x = x + L.sinusoidal_positions(positions,
                                   cfg.d_model).astype(x.dtype)[None]

    def blk(p, hh, sc_, cc_):
        return _dec_block(p, cfg, run, hh, positions, enc_out, sc_, cc_,
                          cache_pos, kv_len)

    fn = jax.checkpoint(blk) if run.remat != "none" else blk

    if run.scan_layers:
        def body(carry, xs_):
            h, (p_l, sc, cc) = carry, xs_
            h, ns, ncr = fn(p_l, h, sc, cc)
            return h, (ns, ncr)

        x, (new_self, new_cross) = lax.scan(
            body, x, (params["dec_blocks"], self_cache, cross_cache))
    else:
        selfs, crosses = [], []
        for i in range(cfg.num_layers):
            p_l = jax.tree.map(lambda a: a[i], params["dec_blocks"])
            sc = (None if self_cache is None
                  else jax.tree.map(lambda a: a[i], self_cache))
            cc = (None if cross_cache is None
                  else jax.tree.map(lambda a: a[i], cross_cache))
            x, ns, ncr = fn(p_l, x, sc, cc)
            selfs.append(ns)
            crosses.append(ncr)
        new_self = (None if self_cache is None else
                    jax.tree.map(lambda *xs: jnp.stack(xs), *selfs))
        new_cross = (None if cross_cache is None else
                     jax.tree.map(lambda *xs: jnp.stack(xs), *crosses))
    return L.rmsnorm(params["dec_ln_f"], x, cfg, run), new_self, new_cross


def forward(params, cfg, run, batch):
    enc_out = encode(params, cfg, run, batch["frames"])
    x, _, _ = _run_decoder(params, cfg, run, batch["tokens"], enc_out, 0)
    return x


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    return {
        "self": L.kv_cache_defs(cfg, cfg.num_layers, batch, max_len),
        "cross": L.kv_cache_defs(cfg, cfg.num_layers, batch,
                                 cfg.encoder_frames),
    }


def prefill(params, cfg, run, batch, cache):
    enc_out = encode(params, cfg, run, batch["frames"])
    x, new_self, new_cross = _run_decoder(
        params, cfg, run, batch["tokens"], enc_out, 0,
        self_cache=cache["self"], cross_cache=cache["cross"],
        cache_pos=0, kv_len=batch["tokens"].shape[1])
    logits = L.logits_out(params["embed"], cfg, run, x[:, -1:])
    return logits, {"self": new_self, "cross": new_cross}


def decode(params, cfg, run, tokens, cache, pos):
    x, new_self, new_cross = _run_decoder(
        params, cfg, run, tokens, None, pos,
        self_cache=cache["self"], cross_cache=cache["cross"],
        cache_pos=pos, kv_len=pos + 1)
    logits = L.logits_out(params["embed"], cfg, run, x)
    return logits, {"self": new_self, "cross": new_cross}
