"""Model-zoo registry: family -> module dispatch + abstract input specs.

Every model module exposes the same functional surface:

    param_defs(cfg)                      -> ParamDef tree
    forward(params, cfg, run, batch)     -> final hidden states (B, S, d)
    cache_defs(cfg, batch, max_len)      -> decode-state ParamDef tree
    prefill(params, cfg, run, batch, cache) -> (logits, cache)
    decode(params, cfg, run, tokens, cache, pos) -> (logits, cache)

``input_specs`` builds ShapeDtypeStruct stand-ins for every model input of
an (arch x shape) cell — the dry-run feeds these to ``jit(...).lower()``
without allocating anything.  Modality frontends (whisper mel conv, llava
vision tower) are STUBS per the assignment: the specs carry precomputed
frame/patch embeddings.
"""
from __future__ import annotations

from types import ModuleType
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import hybrid, mamba2, transformer, whisper

Params = Dict[str, Any]

_FAMILY_MODULES: Dict[str, ModuleType] = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba2,
    "hybrid": hybrid,
    "encdec": whisper,
}


def module_for(cfg: ModelConfig) -> ModuleType:
    try:
        return _FAMILY_MODULES[cfg.family]
    except KeyError:
        raise KeyError(f"unknown model family {cfg.family!r}") from None


def param_defs(cfg: ModelConfig) -> Params:
    return module_for(cfg).param_defs(cfg)


def forward(params: Params, cfg: ModelConfig, run: RunConfig,
            batch: Dict[str, Any]) -> jax.Array:
    return module_for(cfg).forward(params, cfg, run, batch)


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    return module_for(cfg).cache_defs(cfg, batch, max_len)


def prefill(params: Params, cfg: ModelConfig, run: RunConfig,
            batch: Dict[str, Any], cache: Params):
    return module_for(cfg).prefill(params, cfg, run, batch, cache)


def decode(params: Params, cfg: ModelConfig, run: RunConfig,
           tokens: jax.Array, cache: Params, pos):
    return module_for(cfg).decode(params, cfg, run, tokens, cache, pos)


# ---------------------------------------------------------------------------
# Abstract input specs (dry-run)
# ---------------------------------------------------------------------------


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract batch for one train step: tokens + labels (+ stub modality)."""
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
        # 0/1 mask: padded or cross-document-boundary positions drop out of
        # the loss (the carousel packer emits this alongside the tokens).
        "loss_mask": _sds((B, S), jnp.float32),
    }
    if cfg.family == "encdec":
        specs["frames"] = _sds((B, cfg.encoder_frames, cfg.d_model),
                               jnp.bfloat16)
    if cfg.family == "vlm":
        specs["img_embeds"] = _sds((B, cfg.num_img_patches, cfg.d_model),
                                   jnp.bfloat16)
    return specs


def prefill_input_specs(cfg: ModelConfig,
                        shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.family == "encdec":
        specs["frames"] = _sds((B, cfg.encoder_frames, cfg.d_model),
                               jnp.bfloat16)
    if cfg.family == "vlm":
        specs["img_embeds"] = _sds((B, cfg.num_img_patches, cfg.d_model),
                                   jnp.bfloat16)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """One decode step: new token (B, 1) + current position scalar."""
    B = shape.global_batch
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)


# ---------------------------------------------------------------------------
# Concrete input synthesis (smoke tests / examples) — mirrors input_specs.
# ---------------------------------------------------------------------------


def synth_inputs(rng: jax.Array, cfg: ModelConfig, shape: ShapeConfig,
                 kind: Optional[str] = None) -> Dict[str, Any]:
    kind = kind or shape.kind
    B, S = shape.global_batch, shape.seq_len
    k1, k2, k3 = jax.random.split(rng, 3)
    if kind == "decode":
        return {
            "tokens": jax.random.randint(k1, (B, 1), 0, cfg.vocab_size,
                                         jnp.int32),
            "pos": jnp.asarray(S // 2, jnp.int32),
        }
    out: Dict[str, Any] = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size, jnp.int32),
    }
    if kind == "train":
        out["labels"] = jax.random.randint(k2, (B, S), 0, cfg.vocab_size,
                                           jnp.int32)
        out["loss_mask"] = jnp.ones((B, S), jnp.float32)
    if cfg.family == "encdec":
        out["frames"] = (jax.random.normal(
            k3, (B, cfg.encoder_frames, cfg.d_model), jnp.float32) * 0.02
        ).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        out["img_embeds"] = (jax.random.normal(
            k3, (B, cfg.num_img_patches, cfg.d_model), jnp.float32) * 0.02
        ).astype(jnp.bfloat16)
    return out
