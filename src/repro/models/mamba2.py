"""Mamba2 (SSD — state-space duality) language model, pure JAX.

Per-block structure (arXiv:2405.21060):
  in projections (z, x, B, C, dt)  ->  causal depthwise conv on (x, B, C)
  -> SSD scan  ->  gated RMSNorm  ->  out projection.

Projections are SPLIT (not fused) so every sharded feature dim divides the
model axis cleanly (the fused mamba2 in_proj dim 2*d_in+2GN+H rarely
divides 16).  SSD head dim shards on the model axis iff divisible
(zamba2: 64 heads -> sharded; mamba2-130m: 24 heads -> replicated inner
scan, projections still sharded).

Decode state is O(1): conv tails (W-1 tokens) + SSM state (H, P, N).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.kernels import ops
from repro.models import layers as L
from repro.models.params import pdef
from repro.sharding import constrain

Params = Dict[str, Any]
G = 1  # number of B/C groups (mamba2 default ngroups=1)


def block_defs(cfg: ModelConfig, n: int) -> Params:
    d, din = cfg.d_model, cfg.ssm_inner
    N, H, W = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    lead, ll = ((n,), ("layers",)) if n else ((), ())
    return {
        "ln": L.norm_defs(n, d),
        "w_z": pdef(lead + (d, din), ll + ("embed", "ffn"), init="scaled"),
        "w_x": pdef(lead + (d, din), ll + ("embed", "ffn"), init="scaled"),
        "w_B": pdef(lead + (d, G * N), ll + ("embed", None), init="scaled"),
        "w_C": pdef(lead + (d, G * N), ll + ("embed", None), init="scaled"),
        "w_dt": pdef(lead + (d, H), ll + ("embed", None), init="scaled"),
        "conv_x": pdef(lead + (W, din), ll + (None, "ffn"), init="scaled"),
        "conv_B": pdef(lead + (W, G * N), ll + (None, None), init="scaled"),
        "conv_C": pdef(lead + (W, G * N), ll + (None, None), init="scaled"),
        "conv_x_b": pdef(lead + (din,), ll + ("ffn",), init="zeros"),
        "conv_B_b": pdef(lead + (G * N,), ll + (None,), init="zeros"),
        "conv_C_b": pdef(lead + (G * N,), ll + (None,), init="zeros"),
        "A_log": pdef(lead + (H,), ll + (None,), init="ssm_a",
                      dtype=jnp.float32),
        "D": pdef(lead + (H,), ll + (None,), init="ones", dtype=jnp.float32),
        "dt_bias": pdef(lead + (H,), ll + (None,), init="ssm_dt",
                        dtype=jnp.float32),
        "norm": pdef(lead + (din,), ll + ("ffn",), init="ones"),
        "w_out": pdef(lead + (din, d), ll + ("ffn", "embed"), init="scaled"),
    }


def param_defs(cfg: ModelConfig) -> Params:
    return {
        "embed": L.embed_defs(cfg),
        "blocks": block_defs(cfg, cfg.num_layers),
        "ln_f": L.norm_defs(0, cfg.d_model),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B, S, C); w: (W, C); returns (y, new_tail).

    tail: (B, W-1, C) previous context (decode) or None (train: zero pad).
    """
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = lax.conv_general_dilated(
        xp, w[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=x.shape[2])
    new_tail = xp[:, -(W - 1):] if W > 1 else tail
    return jax.nn.silu(y + b), new_tail


def block_fwd(p: Params, cfg: ModelConfig, run: RunConfig, x: jax.Array,
              state: Optional[Params] = None
              ) -> Tuple[jax.Array, Optional[Params]]:
    """x: (B, S, d). state (decode): conv tails + ssm state; None for train."""
    Bb, S, _ = x.shape
    N, H, P = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    h = L.rmsnorm(p["ln"], x, cfg, run)

    z = constrain(h @ p["w_z"], "batch", None, "ffn")
    xs = constrain(h @ p["w_x"], "batch", None, "ffn")
    Bm = h @ p["w_B"]
    Cm = h @ p["w_C"]
    dt = h @ p["w_dt"]

    tails = (None, None, None) if state is None else (
        state["tail_x"], state["tail_B"], state["tail_C"])
    xs, tx = _causal_conv(xs, p["conv_x"], p["conv_x_b"], tails[0])
    Bm, tb = _causal_conv(Bm, p["conv_B"], p["conv_B_b"], tails[1])
    Cm, tc = _causal_conv(Cm, p["conv_C"], p["conv_C_b"], tails[2])

    # shard SSD heads on the model axis when they divide (zamba2: 64H);
    # otherwise shard the head_dim P (mamba2-130m: 24H, P=64) — the rules
    # dedup makes the two tags exclusive.
    xh = constrain(xs.reshape(Bb, S, H, P),
                   "batch", None, "heads_ssm", "ssm_p")
    Bg = Bm.reshape(Bb, S, G, N)
    Cg = Cm.reshape(Bb, S, G, N)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    init = None if state is None else state["ssm"]
    if S == 1 and state is not None:
        # decode: O(1) single-token recurrence — no chunk padding
        y1, new_ssm = ops.ssd_decode(
            xh[:, 0], dtp[:, 0], A, Bg[:, 0], Cg[:, 0], init)
        y = y1[:, None]
    else:
        y, new_ssm = ops.ssd(xh, dtp, A, Bg, Cg, chunk=cfg.ssm_chunk,
                             init_state=init, return_state=True,
                             use_pallas=run.use_pallas)
    y = y + (xh.astype(jnp.float32)
             * p["D"][None, None, :, None]).astype(y.dtype)
    y = constrain(y, "batch", None, "heads_ssm", "ssm_p")
    y = y.reshape(Bb, S, H * P)

    y = ops.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                    p["norm"], eps=cfg.norm_eps, use_pallas=run.use_pallas)
    out = constrain(y @ p["w_out"], "batch", None, None)
    new_state = None
    if state is not None:
        new_state = {"tail_x": tx, "tail_B": tb, "tail_C": tc,
                     "ssm": new_ssm.astype(state["ssm"].dtype)}
    return x + out, new_state


def state_defs(cfg: ModelConfig, n: int, batch: int) -> Params:
    """Decode-state ParamDefs for n stacked mamba blocks."""
    N, H, P, W = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv
    din = cfg.ssm_inner
    lead, ll = ((n,), ("layers",)) if n else ((), ())
    return {
        "tail_x": pdef(lead + (batch, W - 1, din),
                       ll + ("batch", None, "ffn"), init="zeros"),
        "tail_B": pdef(lead + (batch, W - 1, G * N),
                       ll + ("batch", None, None), init="zeros"),
        "tail_C": pdef(lead + (batch, W - 1, G * N),
                       ll + ("batch", None, None), init="zeros"),
        "ssm": pdef(lead + (batch, H, P, N),
                    ll + ("batch", "heads_ssm", "ssm_p", None), init="zeros",
                    dtype=jnp.float32),
    }


def _run_blocks(params, cfg, run, x, state=None):
    def body(carry, xs_):
        h = carry
        p_l, s_l = xs_
        fn = lambda p, hh, ss: block_fwd(p, cfg, run, hh, ss)
        if run.remat != "none":
            fn = jax.checkpoint(fn)
        h, new_s = fn(p_l, h, s_l)
        return h, new_s

    if run.scan_layers:
        x, new_state = lax.scan(body, x, (params["blocks"], state))
    else:
        fn = lambda p, hh, ss: block_fwd(p, cfg, run, hh, ss)
        if run.remat != "none":
            fn = jax.checkpoint(fn)
        outs = []
        for i in range(cfg.num_layers):
            p_l = jax.tree.map(lambda a: a[i], params["blocks"])
            s_l = (None if state is None
                   else jax.tree.map(lambda a: a[i], state))
            x, ns = fn(p_l, x, s_l)
            outs.append(ns)
        new_state = (None if state is None
                     else jax.tree.map(lambda *s: jnp.stack(s), *outs))
    return L.rmsnorm(params["ln_f"], x, cfg, run), new_state


def forward(params, cfg, run, batch):
    x = L.embed(params["embed"], batch["tokens"])
    x, _ = _run_blocks(params, cfg, run, x)
    return x


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    return state_defs(cfg, cfg.num_layers, batch)


def prefill(params, cfg, run, batch, cache):
    x = L.embed(params["embed"], batch["tokens"])
    x, cache = _run_blocks(params, cfg, run, x, state=cache)
    logits = L.logits_out(params["embed"], cfg, run, x[:, -1:])
    return logits, cache


def decode(params, cfg, run, tokens, cache, pos):
    x = L.embed(params["embed"], tokens)
    x, cache = _run_blocks(params, cfg, run, x, state=cache)
    logits = L.logits_out(params["embed"], cfg, run, x)
    return logits, cache
