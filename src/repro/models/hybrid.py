"""Zamba2-style hybrid: Mamba2 backbone with a SHARED attention block
applied every ``attn_every`` SSM layers (arXiv:2411.15242; see DESIGN.md
adaptation note — per-application LoRA adapters are omitted, the shared
attention+MLP block and its placement period are kept).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L
from repro.models import mamba2 as M

Params = Dict[str, Any]


def n_attn_applications(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.attn_every


def param_defs(cfg: ModelConfig) -> Params:
    return {
        "embed": L.embed_defs(cfg),
        "blocks": M.block_defs(cfg, cfg.num_layers),
        "shared_attn": {
            "ln1": L.norm_defs(0, cfg.d_model),
            "attn": L.attention_defs(cfg, 0),
            "ln2": L.norm_defs(0, cfg.d_model),
            "mlp": L.mlp_defs(cfg, 0),
        },
        "ln_f": L.norm_defs(0, cfg.d_model),
    }


def _shared_attn(p: Params, cfg: ModelConfig, run: RunConfig, x: jax.Array,
                 positions, cache_l, cache_pos, kv_len):
    h = L.rmsnorm(p["ln1"], x, cfg, run)
    h, new_cache = L.attention(p["attn"], cfg, run, h, positions=positions,
                               cache=cache_l, cache_pos=cache_pos,
                               kv_len=kv_len)
    x = x + h
    h = L.rmsnorm(p["ln2"], x, cfg, run)
    return x + L.mlp(p["mlp"], cfg, run, h), new_cache


def _run(params, cfg, run, x, positions, mamba_state=None, kv_cache=None,
         cache_pos=None, kv_len=None):
    """Groups of `attn_every` scanned mamba layers + one shared-attn hit."""
    k = cfg.attn_every
    n_app = n_attn_applications(cfg)
    rem = cfg.num_layers - n_app * k
    blocks = params["blocks"]

    def mamba_body(carry, xs_):
        h, p_l, s_l = carry, xs_[0], xs_[1]
        fn = lambda p, hh, ss: M.block_fwd(p, cfg, run, hh, ss)
        if run.remat != "none":
            fn = jax.checkpoint(fn)
        h, ns = fn(p_l, h, s_l)
        return h, ns

    def run_group(x, blk, st):
        if run.scan_layers:
            return lax.scan(mamba_body, x, (blk, st))
        outs = []
        nlayers = jax.tree.leaves(blk)[0].shape[0]
        for i in range(nlayers):
            p_l = jax.tree.map(lambda a: a[i], blk)
            s_l = None if st is None else jax.tree.map(lambda a: a[i], st)
            x, ns = mamba_body(x, (p_l, s_l))
            outs.append(ns)
        ns_all = (None if st is None
                  else jax.tree.map(lambda *s: jnp.stack(s), *outs))
        return x, ns_all

    def group_slice(tree, lo, hi):
        return jax.tree.map(lambda a: a[lo:hi], tree)

    new_states, new_kv = [], []
    for g in range(n_app):
        blk = group_slice(blocks, g * k, (g + 1) * k)
        st = (None if mamba_state is None
              else group_slice(mamba_state, g * k, (g + 1) * k))
        x, ns = run_group(x, blk, st)
        new_states.append(ns)
        c_l = (None if kv_cache is None
               else jax.tree.map(lambda a: a[g], kv_cache))
        x, nc = _shared_attn(params["shared_attn"], cfg, run, x, positions,
                             c_l, cache_pos, kv_len)
        new_kv.append(nc)
    if rem:
        blk = group_slice(blocks, n_app * k, cfg.num_layers)
        st = (None if mamba_state is None
              else group_slice(mamba_state, n_app * k, cfg.num_layers))
        x, ns = run_group(x, blk, st)
        new_states.append(ns)

    out_state = (None if mamba_state is None else
                 jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_states))
    out_kv = (None if kv_cache is None else
              jax.tree.map(lambda *xs: jnp.stack(xs), *new_kv))
    return L.rmsnorm(params["ln_f"], x, cfg, run), out_state, out_kv


def forward(params, cfg, run, batch):
    x = L.embed(params["embed"], batch["tokens"])
    positions = jnp.arange(x.shape[1])
    x, _, _ = _run(params, cfg, run, x, positions)
    return x


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    return {
        "mamba": M.state_defs(cfg, cfg.num_layers, batch),
        "kv": L.kv_cache_defs(cfg, n_attn_applications(cfg), batch, max_len),
    }


def prefill(params, cfg, run, batch, cache):
    x = L.embed(params["embed"], batch["tokens"])
    S = x.shape[1]
    positions = jnp.arange(S)
    x, ms, kv = _run(params, cfg, run, x, positions,
                     mamba_state=cache["mamba"], kv_cache=cache["kv"],
                     cache_pos=0, kv_len=S)
    logits = L.logits_out(params["embed"], cfg, run, x[:, -1:])
    return logits, {"mamba": ms, "kv": kv}


def decode(params, cfg, run, tokens, cache, pos):
    x = L.embed(params["embed"], tokens)
    positions = pos + jnp.arange(1)
    x, ms, kv = _run(params, cfg, run, x, positions,
                     mamba_state=cache["mamba"], kv_cache=cache["kv"],
                     cache_pos=pos, kv_len=pos + 1)
    logits = L.logits_out(params["embed"], cfg, run, x)
    return logits, {"mamba": ms, "kv": kv}
