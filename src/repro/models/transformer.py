"""Decoder-only transformer backbone: dense (llama/qwen/yi/starcoder style),
MoE (mixtral/qwen3-moe), and VLM (llava = backbone + stub patch embeddings).

Layers are stacked along a leading L dim and executed with lax.scan
(+ configurable remat) so the 94-layer MoE lowers to a compact HLO.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L
from repro.sharding import constrain

Params = Dict[str, Any]


def param_defs(cfg: ModelConfig) -> Params:
    n = cfg.num_layers
    block: Params = {
        "ln1": L.norm_defs(n, cfg.d_model),
        "attn": L.attention_defs(cfg, n),
        "ln2": L.norm_defs(n, cfg.d_model),
    }
    if cfg.family == "moe":
        block["moe"] = L.moe_defs(cfg, n)
    else:
        block["mlp"] = L.mlp_defs(cfg, n)
    return {
        "embed": L.embed_defs(cfg),
        "blocks": block,
        "ln_f": L.norm_defs(0, cfg.d_model),
    }


def _remat(fn, run: RunConfig):
    if run.remat == "none":
        return fn
    if run.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _block(p_l: Params, cfg: ModelConfig, run: RunConfig, x: jax.Array,
           positions: jax.Array, cache_l: Optional[Params], cache_pos,
           kv_len) -> Tuple[jax.Array, Optional[Params]]:
    h = L.rmsnorm(p_l["ln1"], x, cfg, run)
    h, new_cache = L.attention(
        p_l["attn"], cfg, run, h, positions=positions,
        cache=cache_l, cache_pos=cache_pos, kv_len=kv_len)
    x = x + h
    h = L.rmsnorm(p_l["ln2"], x, cfg, run)
    if cfg.family == "moe":
        h = L.moe_block(p_l["moe"], cfg, run, h)
    else:
        h = L.mlp(p_l["mlp"], cfg, run, h)
    return x + h, new_cache


def _run_blocks(params: Params, cfg: ModelConfig, run: RunConfig,
                x: jax.Array, positions: jax.Array,
                cache: Optional[Params] = None, cache_pos=None,
                kv_len=None) -> Tuple[jax.Array, Optional[Params]]:
    blocks = params["blocks"]

    if run.scan_layers:
        def body(carry, xs):
            h = carry
            p_l, c_l = xs
            h, new_c = _remat(
                lambda p, hh, cc: _block(p, cfg, run, hh, positions, cc,
                                         cache_pos, kv_len), run)(p_l, h, c_l)
            return h, new_c

        x, new_cache = lax.scan(body, x, (blocks, cache))
    else:
        n = cfg.num_layers
        new_layers = []
        blk_fn = _remat(
            lambda p, hh, cc: _block(p, cfg, run, hh, positions,
                                     cc, cache_pos, kv_len), run)
        for i in range(n):
            p_l = jax.tree.map(lambda a: a[i], blocks)
            c_l = (None if cache is None
                   else jax.tree.map(lambda a: a[i], cache))
            x, nc = blk_fn(p_l, x, c_l)
            new_layers.append(nc)
        new_cache = (None if cache is None else
                     jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers))
    x = L.rmsnorm(params["ln_f"], x, cfg, run)
    return x, new_cache


def _embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, Any]):
    x = L.embed(params["embed"], batch["tokens"])
    if cfg.family == "vlm" and "img_embeds" in batch:
        img = batch["img_embeds"].astype(x.dtype)
        x = jnp.concatenate([img, x], axis=1)
    return constrain(x, "batch", None, None)


def forward(params: Params, cfg: ModelConfig, run: RunConfig,
            batch: Dict[str, Any]) -> jax.Array:
    """Training forward -> final hidden states (B, S_total, d)."""
    x = _embed_inputs(params, cfg, batch)
    positions = jnp.arange(x.shape[1])
    x, _ = _run_blocks(params, cfg, run, x, positions)
    return x


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    return L.kv_cache_defs(cfg, cfg.num_layers, batch, max_len)


def prefill(params: Params, cfg: ModelConfig, run: RunConfig,
            batch: Dict[str, Any], cache: Params
            ) -> Tuple[jax.Array, Params]:
    """Fill the cache from a (B, S) prompt; return last-position logits."""
    x = _embed_inputs(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    x, cache = _run_blocks(params, cfg, run, x, positions,
                           cache=cache, cache_pos=0, kv_len=S)
    logits = L.logits_out(params["embed"], cfg, run, x[:, -1:])
    return logits, cache


def decode(params: Params, cfg: ModelConfig, run: RunConfig,
           tokens: jax.Array, cache: Params, pos: jax.Array
           ) -> Tuple[jax.Array, Params]:
    """One decode step. tokens: (B, 1); pos: scalar current length."""
    x = L.embed(params["embed"], tokens)
    positions = pos + jnp.arange(1)
    x, cache = _run_blocks(params, cfg, run, x, positions,
                           cache=cache, cache_pos=pos, kv_len=pos + 1)
    logits = L.logits_out(params["embed"], cfg, run, x)
    return logits, cache
