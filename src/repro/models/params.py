"""Lightweight functional parameter system (no flax).

A model definition is a function ``param_defs(cfg) -> pytree of ParamDef``.
From that single tree we derive:

* ``abstract(defs)``      -> ShapeDtypeStruct tree (dry-run, no allocation)
* ``materialize(rng, defs)`` -> concrete jnp arrays (smoke tests, examples)
* ``logical_specs(defs)`` -> tree of logical-axis tuples, resolved to
  PartitionSpecs by ``sharding/rules.py`` against a concrete mesh.

Logical axis names used throughout the model zoo:

  "embed"   d_model dim            -> FSDP-sharded on the data axis
  "heads"   attention head dim     -> model axis (iff divisible)
  "qkv"     flattened q/k/v dim    -> model axis (iff divisible)
  "ffn"     MLP hidden dim         -> model axis
  "vocab"   vocabulary dim         -> model axis
  "expert"  MoE expert dim         -> model axis (expert parallelism)
  "layers"  stacked-layer dim      -> never sharded
  None      replicated
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Logical = Tuple[Optional[str], ...]


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Logical  # one logical axis name (or None) per dim
    init: str = "normal"  # normal | zeros | ones | scaled | ssm_a | ssm_dt
    scale: float = 0.02
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def pdef(shape, logical, init="normal", scale=0.02,
         dtype=jnp.bfloat16) -> ParamDef:
    return ParamDef(tuple(shape), tuple(logical), init, scale, dtype)


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def tree_map(f: Callable[[ParamDef], Any], defs: Any) -> Any:
    return jax.tree.map(f, defs, is_leaf=is_def)


def abstract(defs: Any) -> Any:
    """ShapeDtypeStruct tree — what the dry-run feeds to .lower()."""
    return tree_map(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs)


def logical_specs(defs: Any) -> Any:
    return tree_map(lambda d: d.logical, defs)


def param_count(defs: Any) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


def param_bytes(defs: Any) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
               for d in leaves)


def _init_leaf(key: jax.Array, d: ParamDef) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "normal":
        return (jax.random.normal(key, d.shape, jnp.float32)
                * d.scale).astype(d.dtype)
    if d.init == "scaled":  # fan-in scaled
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        s = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, d.shape, jnp.float32)
                * s).astype(d.dtype)
    if d.init == "ssm_a":  # Mamba2 A_log init: log of Uniform[1, 16]
        u = jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(d.dtype)
    if d.init == "ssm_dt":  # dt bias: inverse-softplus of Uniform[1e-3, 1e-1]
        u = jax.random.uniform(key, d.shape, jnp.float32, 0.001, 0.1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(d.dtype)
    raise ValueError(f"unknown init {d.init!r}")


def materialize(rng: jax.Array, defs: Any) -> Any:
    """Instantiate real parameters (smoke tests / examples / training)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(rng, len(leaves))
    arrs = [_init_leaf(k, d) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def cast_tree(tree: Any, dtype: Any) -> Any:
    return jax.tree.map(lambda x: x.astype(dtype), tree)
