"""Training step: grads (with microbatch accumulation) + AdamW update.

The state is a plain dict pytree — params, optimizer moments, step — so
sharding/checkpointing treat everything uniformly.  ``make_train_step``
returns a pure ``(state, batch) -> (state, metrics)`` for jit; the launch
layer wraps it with in/out shardings resolved from the param defs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.models import params as P
from repro.models import registry
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.train.loss import lm_loss

TrainState = Dict[str, Any]


def init_state(rng: jax.Array, cfg: ModelConfig, run: RunConfig) -> TrainState:
    defs = registry.param_defs(cfg)
    params = P.materialize(rng, defs)
    opt = adamw_init(params, dtype=jnp.dtype(run.opt_state_dtype))
    return {"params": params, "opt": opt}


def abstract_state(cfg: ModelConfig, run: RunConfig) -> TrainState:
    """ShapeDtypeStruct state tree (dry-run: no allocation)."""
    defs = registry.param_defs(cfg)
    params = P.abstract(defs)
    dt = jnp.dtype(run.opt_state_dtype)
    mom = P.tree_map(lambda d: jax.ShapeDtypeStruct(d.shape, dt), defs)
    return {
        "params": params,
        "opt": {"m": mom, "v": jax.tree.map(lambda x: x, mom),
                "step": jax.ShapeDtypeStruct((), jnp.int32)},
    }


def _split_microbatches(batch: Dict[str, Any], accum: int) -> Dict[str, Any]:
    def split(x):
        B = x.shape[0]
        assert B % accum == 0, (B, accum)
        return x.reshape(accum, B // accum, *x.shape[1:])
    return jax.tree.map(split, batch)


def grads_and_metrics(params, cfg: ModelConfig, run: RunConfig,
                      batch: Dict[str, Any]):
    """Value-and-grad with optional lax.scan gradient accumulation."""
    loss_fn = lambda p, b: lm_loss(p, cfg, run, b)

    if run.accum_steps <= 1:
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return grads, {"loss": loss, **aux}

    mb = _split_microbatches(batch, run.accum_steps)

    def body(carry, mbatch):
        g_acc, l_acc = carry
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mbatch)
        g_acc = jax.tree.map(
            lambda a, b: a + b.astype(a.dtype), g_acc, g)
        return (g_acc, l_acc + loss), None

    g0 = jax.tree.map(  # accumulate in fp32 regardless of param dtype
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (g_sum, l_sum), _ = lax.scan(body, (g0, jnp.zeros((), jnp.float32)), mb)
    inv = 1.0 / run.accum_steps
    grads = jax.tree.map(lambda g: (g * inv).astype(g.dtype), g_sum)
    return grads, {"loss": l_sum * inv}


def train_step(state: TrainState, batch: Dict[str, Any], *,
               cfg: ModelConfig, run: RunConfig
               ) -> Tuple[TrainState, Dict[str, jax.Array]]:
    params, opt = state["params"], state["opt"]
    grads, metrics = grads_and_metrics(params, cfg, run, batch)

    if run.grad_compression == "bf16":
        # compress gradients before the data-axis reduction GSPMD inserts;
        # halves all-reduce bytes (see EXPERIMENTS.md §Perf)
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)

    lr = cosine_schedule(opt["step"] + 1, base_lr=run.learning_rate,
                         warmup_steps=run.warmup_steps,
                         total_steps=run.total_steps)
    new_params, new_opt, opt_metrics = adamw_update(
        params, grads, opt, lr=lr,
        weight_decay=run.weight_decay,
        max_grad_norm=run.max_grad_norm)
    metrics.update(opt_metrics)
    return {"params": new_params, "opt": new_opt}, metrics


def make_train_step(cfg: ModelConfig, run: RunConfig):
    """Closure suitable for jax.jit(in_shardings=..., out_shardings=...)."""
    return functools.partial(train_step, cfg=cfg, run=run)
