"""LM loss head.

``ce_blockwise`` is a custom-VJP vocab-blockwise cross entropy: neither the
forward nor the backward pass ever materializes the (T, V) logit matrix —
forward keeps online (max, logsumexp, target-logit) statistics per vocab
block; backward recomputes each block's logits and immediately contracts
them into (d_hidden, d_w) contributions.  At qwen scale
(1M tokens x 152k vocab) direct CE residuals are ~0.6 PB; blockwise is
O(T*D + V*D) — this is what lets the 94-layer MoE train_4k cell fit.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.kernels.ref import NEG_INF, _pad_to
from repro.models import layers as L
from repro.models import registry
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# Blockwise CE with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def ce_blockwise(hidden, w_vocab, targets, valid, block_v: int = 8192,
                 ce_dtype=jnp.bfloat16):
    """Mean NLL over valid positions. hidden: (T, D); w_vocab: (V, D).

    The per-block logits matmul runs with ``ce_dtype`` inputs and f32
    accumulation (§Perf: halves the 19x whole-hidden reads at qwen vocab)."""
    nll, _ = _ce_fwd_stats(hidden, w_vocab, targets, block_v, ce_dtype)
    return _masked_mean(nll, valid)


def _block_logits(h, w_blk, ce_dtype):
    return lax.dot_general(
        h.astype(ce_dtype), w_blk.astype(ce_dtype),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def _masked_mean(nll, valid):
    if valid is not None:
        nll = nll * valid
        return nll.sum() / jnp.maximum(valid.sum(), 1.0)
    return nll.mean()


def _ce_fwd_stats(hidden, w_vocab, targets, block_v,
                  ce_dtype=jnp.bfloat16):
    T, D = hidden.shape
    V = w_vocab.shape[0]
    block_v = min(block_v, V)
    wp, _ = _pad_to(w_vocab, 0, block_v)
    nb = wp.shape[0] // block_v
    hf = hidden
    wb = wp.reshape(nb, block_v, D)

    def body(carry, blk):
        m, l, tgt = carry
        w_blk, j = blk
        logits = _block_logits(hf, w_blk, ce_dtype)  # (T, block_v) f32
        logits = constrain(logits, "batch", "vocab")
        vids = j * block_v + jnp.arange(block_v)
        logits = jnp.where(vids[None, :] < V, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        l_new = (l * jnp.exp(m - m_new)
                 + jnp.exp(logits - m_new[:, None]).sum(-1))
        hit = vids[None, :] == targets[:, None]
        tgt_new = tgt + jnp.where(hit, logits, 0.0).sum(-1)
        return (m_new, l_new, tgt_new), None

    m0 = jnp.full((T,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((T,), jnp.float32)
    t0 = jnp.zeros((T,), jnp.float32)
    (m, l, tgt), _ = lax.scan(body, (m0, l0, t0), (wb, jnp.arange(nb)))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return lse - tgt, lse


def _ce_fwd(hidden, w_vocab, targets, valid, block_v, ce_dtype):
    nll, lse = _ce_fwd_stats(hidden, w_vocab, targets, block_v, ce_dtype)
    loss = _masked_mean(nll, valid)
    return loss, (hidden, w_vocab, targets, valid, lse)


def _ce_bwd(block_v, ce_dtype, res, g):
    hidden, w_vocab, targets, valid, lse = res
    T, D = hidden.shape
    V = w_vocab.shape[0]
    bv = min(block_v, V)
    wp, _ = _pad_to(w_vocab, 0, bv)
    nb = wp.shape[0] // bv
    hf = hidden

    denom = (jnp.maximum(valid.sum(), 1.0) if valid is not None
             else jnp.asarray(float(T), jnp.float32))
    # per-token weight on d nll
    wtok = (valid if valid is not None else jnp.ones((T,), jnp.float32))
    coef = (g * wtok / denom)[:, None]  # (T, 1)

    def body(dh, blk):
        w_blk, j = blk
        logits = constrain(_block_logits(hf, w_blk, ce_dtype),
                           "batch", "vocab")
        vids = j * bv + jnp.arange(bv)
        probs = jnp.exp(logits - lse[:, None])
        probs = jnp.where(vids[None, :] < V, probs, 0.0)
        hit = (vids[None, :] == targets[:, None]).astype(jnp.float32)
        dlogits = (coef * (probs - hit)).astype(ce_dtype)  # (T, bv)
        dh = dh + lax.dot_general(
            dlogits, w_blk.astype(ce_dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dw_blk = lax.dot_general(
            dlogits, hf.astype(ce_dtype), (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)  # (bv, D)
        return dh, dw_blk

    dh0 = jnp.zeros((T, D), jnp.float32)
    wb = wp.reshape(nb, bv, D)
    dh, dwb = lax.scan(body, dh0, (wb, jnp.arange(nb)))
    dw = dwb.reshape(nb * bv, D)[:V]
    return (dh.astype(hidden.dtype), dw.astype(w_vocab.dtype), None, None)


ce_blockwise.defvjp(_ce_fwd, _ce_bwd)


# ---------------------------------------------------------------------------
# Direct CE (baseline path; fine for small vocab / smoke)
# ---------------------------------------------------------------------------


def ce_direct(hidden, w_vocab, targets, valid):
    logits = jnp.einsum("td,vd->tv", hidden.astype(jnp.float32),
                        w_vocab.astype(jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return _masked_mean(lse - tgt, valid)


# ---------------------------------------------------------------------------
# Model loss
# ---------------------------------------------------------------------------


def lm_loss(params, cfg: ModelConfig, run: RunConfig,
            batch: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token LM loss for any arch in the zoo."""
    x = registry.forward(params, cfg, run, batch)  # (B, S_total, d)
    if cfg.family == "vlm":
        x = x[:, cfg.num_img_patches:]  # loss over text positions only
    B, S, D = x.shape
    x = constrain(x, "batch", None, None)

    hidden = x.reshape(B * S, D)
    targets = batch["labels"].reshape(B * S)
    valid = batch.get("loss_mask")
    valid = valid.reshape(B * S) if valid is not None else None
    w = L.lm_head_weight(params["embed"], cfg)

    if run.ce_mode == "blockwise":
        loss = ce_blockwise(hidden, w, targets, valid, run.ce_block_v,
                            jnp.dtype(run.ce_dtype))
    else:
        loss = ce_direct(hidden, w, targets, valid)
    ntok = (valid.sum() if valid is not None
            else jnp.asarray(B * S, jnp.float32))
    return loss, {"loss": loss, "tokens": ntok}
