from repro.train.loss import lm_loss  # noqa: F401
from repro.train.step import (  # noqa: F401
    TrainState,
    init_state,
    make_train_step,
    train_step,
)
