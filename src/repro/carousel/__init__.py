"""Data Carousel: fine-grained, incremental data delivery (paper §3.1).

ColdStore (tape) -> Stager (async, hedged, retried) -> DiskCache (bounded,
prompt release) -> on-demand transform -> DeliveryIterator (training
batches as shards land).  ``simulator.py`` is the discrete-event model
that reproduces the paper's Fig. 4/5 comparison (coarse vs fine).
"""
from repro.carousel.storage import ColdStore, DiskCache, TapeFile  # noqa: F401
from repro.carousel.stager import Stager  # noqa: F401
from repro.carousel.ddm import CarouselDDM  # noqa: F401
from repro.carousel.delivery import DeliveryIterator  # noqa: F401
