"""Delivery iterator: the consumer end of the carousel.

Yields fixed-size training batches to the training loop *as shards land*
(fine granularity — processing starts with the first staged file, exactly
the paper's optimum), with double-buffered host->device prefetch so the
input pipeline overlaps with compute.  ``coarse=True`` reproduces the
pre-iDDS baseline: block until the whole collection is staged.

Row conservation: every row of every successfully staged shard is
delivered exactly once — the final partial batch (fewer than
``batch_rows`` rows) is emitted too.  Shards that fail staging
terminally are skipped and recorded (``failed_shards`` /
``skipped_shards``) in both modes; if *every* shard failed, iteration
raises instead of silently yielding nothing.  Deadlines use the
monotonic clock.

Consumed rows are released from the DiskCache promptly (pin/release per
shard), keeping the disk footprint at O(open shards), not O(dataset).
"""
from __future__ import annotations

import collections
import time
from typing import Any, Dict, Iterator, List, Optional

import numpy as np

from repro.carousel.stager import Stager
from repro.carousel.storage import DiskCache


class DeliveryIterator:
    def __init__(self, stager: Stager, cache: DiskCache, names: List[str], *,
                 batch_rows: int, coarse: bool = False,
                 device_put: Optional[Any] = None,
                 prefetch: int = 2, timeout: float = 120.0):
        self.stager = stager
        self.cache = cache
        self.names = list(names)
        self.batch_rows = batch_rows
        self.coarse = coarse
        self.device_put = device_put
        self.prefetch = max(1, prefetch)
        self.timeout = timeout
        self.first_batch_at: Optional[float] = None   # monotonic
        self.started_at: Optional[float] = None       # monotonic
        self.batches_delivered = 0
        self.rows_delivered = 0
        self.failed_shards = 0
        self.skipped_shards: List[str] = []

    def _record_failed(self, failed) -> None:
        self.failed_shards += len(failed)
        self.skipped_shards.extend(sorted(failed))
        if self.names and self.failed_shards >= len(self.names):
            raise RuntimeError(
                f"all {len(self.names)} shards failed staging: "
                f"{self.skipped_shards[:5]}")

    # -- shard arrival order (fine mode consumes in landing order) ----------
    def _iter_ready_shards(self) -> Iterator[str]:
        remaining = set(self.names)
        deadline = time.monotonic() + self.timeout
        if self.coarse:
            # baseline: wait for the ENTIRE collection before any delivery
            if not self.stager.wait(timeout=self.timeout):
                raise TimeoutError("coarse staging timed out")
            failed = set(self.stager.failed()) & remaining
            if failed:
                # skip-with-record, mirroring fine mode (and raise when
                # nothing at all survived staging)
                remaining -= failed
                self._record_failed(failed)
            for n in self.names:
                if n in remaining and n in self.cache:
                    remaining.discard(n)
                    yield n
            return
        while remaining:
            self.stager.hedge_check()
            landed = [n for n in list(remaining) if n in self.cache]
            for n in landed:
                remaining.discard(n)
                yield n
            if not landed:
                failed = set(self.stager.failed()) & remaining
                if failed:
                    remaining -= failed  # skip terminally-failed shards
                    self._record_failed(failed)
                if not remaining:
                    return
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        "fine staging timed out; missing "
                        f"{sorted(remaining)[:5]}")
                time.sleep(0.002)

    # -- batch assembly -------------------------------------------------------
    def __iter__(self) -> Iterator[Dict[str, Any]]:
        self.started_at = time.monotonic()
        rows: Dict[str, List[np.ndarray]] = collections.defaultdict(list)
        n_rows = 0
        pending: collections.deque = collections.deque()

        def emit(batch_np: Dict[str, np.ndarray]):
            out = (self.device_put(batch_np) if self.device_put is not None
                   else batch_np)
            pending.append(out)

        def drain(force: bool = False):
            while pending and (force or len(pending) >= self.prefetch):
                b = pending.popleft()
                if self.first_batch_at is None:
                    self.first_batch_at = time.monotonic()
                self.batches_delivered += 1
                yield b

        for name in self._iter_ready_shards():
            self.cache.pin(name)
            shard = self.cache.get(name)
            for k, v in shard.items():
                rows[k].append(v)
            n_rows += next(iter(shard.values())).shape[0]
            self.cache.release(name, drop=True)  # prompt release

            while n_rows >= self.batch_rows:
                batch = {k: np.concatenate(v) for k, v in rows.items()}
                head = {k: v[:self.batch_rows] for k, v in batch.items()}
                tail = {k: v[self.batch_rows:] for k, v in batch.items()}
                rows = collections.defaultdict(list)
                for k, v in tail.items():
                    if v.shape[0]:
                        rows[k].append(v)
                n_rows -= self.batch_rows
                self.rows_delivered += self.batch_rows
                emit(head)
                yield from drain()
        if n_rows > 0:
            # the final partial batch: without this, delivered rows !=
            # dataset rows whenever the dataset isn't a multiple of
            # batch_rows
            batch = {k: np.concatenate(v) for k, v in rows.items()}
            self.rows_delivered += n_rows
            emit(batch)
        yield from drain(force=True)
