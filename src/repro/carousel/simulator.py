"""Discrete-event simulator of a bulk-reprocessing campaign (Figs. 4-5).

Models the regime the paper optimizes: N files on tape, a handful of tape
drives, a bounded disk pool, and a grid of processing workers.  Two
operating modes:

  coarse (pre-iDDS)  — dataset-level granularity.  Jobs are released up
      front; a worker that picks a job before the WHOLE dataset is staged
      burns ``attempt_overhead`` and fails (another *job attempt*), then
      retries after ``retry_interval``.  All files stay on disk until the
      campaign ends ("big disk pools ... during the whole processing
      period").

  fine (iDDS)        — file-level granularity.  A job is created only when
      its file's availability message arrives, so attempts ≈ 1 per file;
      each file is released from disk the moment it is processed.

Shared machinery: tape faults (retried), straggler reads (latency tail),
optional hedged duplicate requests, and disk backpressure (drives stall
when the pool is full and nothing is releasable).

Pure simulated time — no sleeps; a 10^5-file campaign runs in ~a second.
"""
from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple


@dataclass
class SimParams:
    n_files: int = 500
    file_size: float = 8e9              # bytes
    n_drives: int = 8
    mount_latency: float = 45.0         # s per tape read
    bandwidth: float = 400e6            # bytes/s per drive
    n_workers: int = 100
    job_time: float = 1800.0            # s of processing per file
    attempt_overhead: float = 180.0     # s a failed attempt burns on a worker
    retry_interval: float = 900.0       # s between retries (coarse)
    disk_capacity: float = 4e12         # bytes
    granularity: str = "fine"           # fine | coarse
    fault_rate: float = 0.02            # tape read failure probability
    straggler_frac: float = 0.05
    straggler_mult: float = 6.0
    hedge: bool = False
    hedge_factor: float = 3.0
    max_stage_attempts: int = 5
    seed: int = 0


@dataclass
class SimReport:
    params: SimParams
    makespan: float = 0.0
    job_attempts: int = 0
    failed_attempts: int = 0
    stage_attempts: int = 0
    stage_faults: int = 0
    hedges: int = 0
    peak_disk: float = 0.0
    disk_byte_seconds: float = 0.0
    time_to_first_processing: float = float("inf")
    drive_busy_s: float = 0.0
    worker_busy_s: float = 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "granularity": self.params.granularity,
            "makespan_h": self.makespan / 3600,
            "job_attempts": self.job_attempts,
            "failed_attempts": self.failed_attempts,
            "attempts_per_job": (self.job_attempts
                                 / max(self.params.n_files, 1)),
            "peak_disk_TB": self.peak_disk / 1e12,
            "disk_TB_hours": self.disk_byte_seconds / 1e12 / 3600,
            "ttfp_h": self.time_to_first_processing / 3600,
            "stage_attempts": self.stage_attempts,
            "hedges": self.hedges,
        }


class _Sim:
    def __init__(self, p: SimParams):
        self.p = p
        self.rnd = random.Random(p.seed)
        self.now = 0.0
        self._seq = itertools.count()
        self._events: List[Tuple[float, int, Callable[[], None]]] = []
        self.rep = SimReport(params=p)

        # file state
        self.staged = [False] * p.n_files
        self.processed = [False] * p.n_files
        self.stage_attempt = [0] * p.n_files
        self.stage_started_at: Dict[int, float] = {}
        self.on_disk: set = set()

        # resources
        self.free_drives = p.n_drives
        self.free_workers = p.n_workers
        self.stage_queue: List[int] = list(range(p.n_files))
        self.job_queue: List[int] = []       # fine: per-file jobs as staged
        self.retry_heap: List[Tuple[float, int]] = []  # coarse retries

        # disk accounting (reserved = in-flight stages, so concurrent reads
        # can never overshoot the pool)
        self.disk_used = 0.0
        self.disk_reserved = 0.0
        self._last_disk_t = 0.0

        self.n_done = 0
        self.all_staged_at: Optional[float] = None

    # -- core event loop ---------------------------------------------------
    def at(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._events, (t, next(self._seq), fn))

    def run(self) -> SimReport:
        if self.p.granularity == "coarse":
            # all jobs pre-released; workers start grabbing immediately
            self.job_queue = list(range(self.p.n_files))
        self._kick_drives()
        self._kick_workers()
        guard = 0
        while self._events and self.n_done < self.p.n_files:
            t, _, fn = heapq.heappop(self._events)
            self._tick_disk(t)
            self.now = t
            fn()
            guard += 1
            if guard > 50_000_000:
                raise RuntimeError("sim runaway")
        self.rep.makespan = self.now
        self.rep.peak_disk = max(self.rep.peak_disk, self.disk_used)
        if self.n_done < self.p.n_files:
            raise RuntimeError(
                f"sim deadlock: {self.n_done}/{self.p.n_files} done "
                f"(disk {self.disk_used/1e12:.1f}"
                f"/{self.p.disk_capacity/1e12:.1f} TB)")
        return self.rep

    def _tick_disk(self, t: float) -> None:
        self.rep.disk_byte_seconds += self.disk_used * (t - self._last_disk_t)
        self._last_disk_t = t
        self.rep.peak_disk = max(self.rep.peak_disk, self.disk_used)

    # -- staging side --------------------------------------------------------
    def _stage_duration(self, i: int) -> float:
        base = self.p.mount_latency + self.p.file_size / self.p.bandwidth
        if self.rnd.random() < self.p.straggler_frac:
            base *= self.p.straggler_mult
        return base

    def _disk_fits(self) -> bool:
        return (self.disk_used + self.disk_reserved + self.p.file_size
                <= self.p.disk_capacity)

    def _kick_drives(self) -> None:
        while self.free_drives > 0 and self.stage_queue and self._disk_fits():
            i = self.stage_queue.pop(0)
            if self.staged[i]:
                continue
            self.free_drives -= 1
            self.disk_reserved += self.p.file_size
            self.stage_attempt[i] += 1
            self.rep.stage_attempts += 1
            self.stage_started_at.setdefault(i, self.now)
            dur = self._stage_duration(i)
            fault = self.rnd.random() < self.p.fault_rate
            self.rep.drive_busy_s += dur
            self.at(self.now + dur, lambda i=i, fault=fault:
                    self._stage_done(i, fault))
        # hedging: spare drives duplicate long-running stages
        if self.p.hedge and self.free_drives > 0 and not self.stage_queue:
            exp = self.p.mount_latency + self.p.file_size / self.p.bandwidth
            for i, t0 in list(self.stage_started_at.items()):
                if self.free_drives <= 0:
                    break
                if (not self.staged[i]
                        and self.now - t0 > self.p.hedge_factor * exp
                        and self.stage_attempt[i] < self.p.max_stage_attempts):
                    self.free_drives -= 1
                    self.disk_reserved += self.p.file_size
                    self.stage_attempt[i] += 1
                    self.rep.stage_attempts += 1
                    self.rep.hedges += 1
                    dur = (self.p.mount_latency
                           + self.p.file_size / self.p.bandwidth)
                    self.rep.drive_busy_s += dur
                    self.at(self.now + dur,
                            lambda i=i: self._stage_done(i, False))

    def _stage_done(self, i: int, fault: bool) -> None:
        self.free_drives += 1
        self.disk_reserved -= self.p.file_size
        if self.staged[i]:          # hedged duplicate landed second
            self._kick_drives()
            return
        if fault:
            self.rep.stage_faults += 1
            if self.stage_attempt[i] < self.p.max_stage_attempts:
                self.stage_queue.append(i)   # retry
            self._kick_drives()
            return
        self.staged[i] = True
        self.stage_started_at.pop(i, None)
        self.disk_used += self.p.file_size
        self.on_disk.add(i)
        if all(self.staged):
            self.all_staged_at = self.now
        if self.p.granularity == "fine":
            # availability message -> job creation (iDDS Conductor path)
            self.job_queue.append(i)
            self._kick_workers()
        self._kick_drives()

    # -- processing side ------------------------------------------------------
    def _kick_workers(self) -> None:
        # wake any due retries
        while self.retry_heap and self.retry_heap[0][0] <= self.now:
            _, i = heapq.heappop(self.retry_heap)
            self.job_queue.append(i)
        while self.free_workers > 0 and self.job_queue:
            i = self.job_queue.pop(0)
            if self.processed[i]:
                continue
            self.free_workers -= 1
            if self.p.granularity == "coarse" and not all(self.staged):
                # job attempt before the dataset is complete: burn + fail
                self.rep.job_attempts += 1
                self.rep.failed_attempts += 1
                self.rep.worker_busy_s += self.p.attempt_overhead
                self.at(self.now + self.p.attempt_overhead,
                        lambda i=i: self._attempt_failed(i))
            else:
                self.rep.job_attempts += 1
                self.rep.time_to_first_processing = min(
                    self.rep.time_to_first_processing, self.now)
                self.rep.worker_busy_s += self.p.job_time
                self.at(self.now + self.p.job_time,
                        lambda i=i: self._job_done(i))

    def _attempt_failed(self, i: int) -> None:
        self.free_workers += 1
        t = self.now + self.p.retry_interval
        heapq.heappush(self.retry_heap, (t, i))
        self.at(t, self._kick_workers)

    def _job_done(self, i: int) -> None:
        self.free_workers += 1
        self.processed[i] = True
        self.n_done += 1
        if self.p.granularity == "fine":
            # prompt release: free the file's disk bytes now
            if i in self.on_disk:
                self.on_disk.discard(i)
                self.disk_used -= self.p.file_size
            self._kick_drives()   # freed disk may unblock staging
        elif self.n_done == self.p.n_files:
            # coarse: the whole dataset is released only at campaign end
            self.disk_used -= self.p.file_size * len(self.on_disk)
            self.on_disk.clear()
        self._kick_workers()


def simulate(params: SimParams) -> SimReport:
    return _Sim(params).run()


def compare(base: Optional[SimParams] = None, **overrides) -> Dict[str, Dict]:
    """Run the paper's comparison: same campaign, coarse vs fine."""
    import dataclasses
    p = base or SimParams()
    p = dataclasses.replace(p, **overrides)
    fine = simulate(dataclasses.replace(p, granularity="fine"))
    # coarse needs the whole dataset on disk at once
    coarse_cap = max(p.disk_capacity, p.n_files * p.file_size * 1.01)
    coarse = simulate(dataclasses.replace(p, granularity="coarse",
                                          disk_capacity=coarse_cap))
    return {"fine": fine.summary(), "coarse": coarse.summary()}
