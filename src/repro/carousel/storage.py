"""Storage tiers: ColdStore (the tape system) and DiskCache (DATADISK).

ColdStore read latency models a tape library: mount/seek latency plus
size/bandwidth, with a limited number of drives (concurrent reads).  For
integration tests the latencies are milliseconds; the discrete-event
simulator bypasses real sleeps entirely and reuses only the latency model.

DiskCache is the bounded staging pool the paper's carousel keeps small:
files are pinned while a consumer processes them and *promptly released*
afterwards; eviction only reclaims released files (LRU).  ``peak_bytes``
and the residence integral are the Fig. 5 metrics.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional


@dataclass
class TapeFile:
    name: str
    size: int                      # bytes
    payload: Any = None            # the actual data (ndarray / bytes / path)
    generator: Optional[Callable[[], Any]] = None  # lazy synth data

    def read(self) -> Any:
        if self.payload is not None:
            return self.payload
        if self.generator is not None:
            return self.generator()
        return None


class ColdStore:
    """Tape-like bulk store: cheap, high-latency, few concurrent drives."""

    def __init__(self, *, drives: int = 2, mount_latency: float = 0.0,
                 bandwidth: float = float("inf"),
                 fault_rate: float = 0.0, straggler_frac: float = 0.0,
                 straggler_mult: float = 10.0, seed: int = 0):
        import random
        self._files: Dict[str, TapeFile] = {}
        self._drives = threading.Semaphore(drives)
        self.n_drives = drives
        self.mount_latency = mount_latency
        self.bandwidth = bandwidth
        self.fault_rate = fault_rate
        self.straggler_frac = straggler_frac   # per-READ tail latency
        self.straggler_mult = straggler_mult
        self._rnd = random.Random(seed)
        self._rnd_lock = threading.Lock()
        self.reads = 0
        self.failed_reads = 0

    def add(self, f: TapeFile) -> None:
        self._files[f.name] = f

    def files(self) -> List[TapeFile]:
        return list(self._files.values())

    def get(self, name: str) -> TapeFile:
        return self._files[name]

    def stage_latency(self, f: TapeFile) -> float:
        return self.mount_latency + (f.size / self.bandwidth
                                     if self.bandwidth != float("inf")
                                     else 0.0)

    def read(self, name: str) -> Any:
        """Blocking staged read through a tape drive (real-time mode)."""
        f = self._files[name]
        with self._drives:
            with self._rnd_lock:
                fail = self._rnd.random() < self.fault_rate
                slow = self._rnd.random() < self.straggler_frac
            lat = self.stage_latency(f)
            if slow:
                lat *= self.straggler_mult  # tail read (per-read, so a
                # hedged duplicate re-read is most likely fast)
            if lat > 0:
                time.sleep(lat)
            self.reads += 1
            if fail:
                self.failed_reads += 1
                raise IOError(f"tape read error on {name}")
            return f.read()


class CacheFullError(Exception):
    pass


class DiskCache:
    """Bounded staging cache with pin/release + LRU eviction of released
    entries.  Tracks the Fig. 5 metrics: peak usage and byte-seconds."""

    def __init__(self, capacity_bytes: int):
        self.capacity = capacity_bytes
        self._lock = threading.RLock()
        self._data: Dict[str, Any] = {}
        self._size: Dict[str, int] = {}
        self._pins: Dict[str, int] = {}
        self._lru: List[str] = []      # released entries, oldest first
        self.used = 0
        self.peak_bytes = 0
        self.evictions = 0
        self._residence_acc = 0.0      # integral of used bytes over time
        # monotonic: a wall-clock step (NTP slew) must not corrupt the
        # byte-seconds integral
        self._last_t = time.monotonic()

    def _tick(self) -> None:
        now = time.monotonic()
        self._residence_acc += self.used * (now - self._last_t)
        self._last_t = now

    @property
    def byte_seconds(self) -> float:
        with self._lock:
            self._tick()
            return self._residence_acc

    def _evict_for(self, need: int) -> bool:
        while self.used + need > self.capacity and self._lru:
            victim = self._lru.pop(0)
            self.used -= self._size.pop(victim)
            self._data.pop(victim, None)
            self._pins.pop(victim, None)
            self.evictions += 1
        return self.used + need <= self.capacity

    def put(self, name: str, data: Any, size: int, *,
            pin: bool = True) -> None:
        with self._lock:
            self._tick()
            if name in self._data:
                if pin:
                    self._pins[name] = self._pins.get(name, 0) + 1
                return
            if not self._evict_for(size):
                raise CacheFullError(
                    f"{name}: need {size}, used {self.used}/{self.capacity} "
                    f"with {len(self._lru)} evictable")
            self._data[name] = data
            self._size[name] = size
            self._pins[name] = 1 if pin else 0
            if not pin:
                self._lru.append(name)
            self.used += size
            self.peak_bytes = max(self.peak_bytes, self.used)

    def get(self, name: str) -> Any:
        with self._lock:
            return self._data[name]

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._data

    def pin(self, name: str) -> None:
        with self._lock:
            self._pins[name] = self._pins.get(name, 0) + 1
            if name in self._lru:
                self._lru.remove(name)

    def release(self, name: str, *, drop: bool = False) -> None:
        """Consumer done with the file. drop=True frees immediately (the
        carousel's prompt release); otherwise it becomes LRU-evictable."""
        with self._lock:
            if name not in self._data:
                return
            self._pins[name] = max(0, self._pins.get(name, 0) - 1)
            if self._pins[name] == 0:
                if drop:
                    self._tick()
                    self.used -= self._size.pop(name)
                    self._data.pop(name)
                    self._pins.pop(name)
                elif name not in self._lru:
                    self._lru.append(name)

    def stats(self) -> Dict[str, float]:
        with self._lock:
            self._tick()
            return {"used": self.used, "peak_bytes": self.peak_bytes,
                    "evictions": self.evictions,
                    "byte_seconds": self._residence_acc,
                    "entries": len(self._data)}
