"""On-demand data transformation (paper function #1).

Raw corpus shards (variable-length tokenized documents) are transformed at
*stage time* into the consumer-optimal format: fixed-length packed
training sequences with next-token labels and a loss mask that zeroes
cross-document positions.  Delivering packed sequences instead of raw
documents minimizes bytes on the wire and removes all consumer-side CPU
work — the iDDS rationale, one level down.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


def pack_documents(docs: Sequence[np.ndarray], seq_len: int, *,
                   pad_id: int = 0, eod_id: int = 1) -> Dict[str, np.ndarray]:
    """Greedy sequential packing of documents into (N, seq_len) rows.

    Returns tokens (N, S) int32, labels (N, S) int32 (next token), and
    loss_mask (N, S) float32 — 0 on pad positions and on the position that
    would predict across a document boundary.
    """
    stream: List[int] = []
    bounds: List[int] = []  # indices in `stream` where a doc ends (eod pos)
    for d in docs:
        stream.extend(int(t) for t in d)
        stream.append(eod_id)
        bounds.append(len(stream) - 1)

    total = len(stream)
    n_rows = max(1, (total + seq_len) // (seq_len + 1))
    need = n_rows * (seq_len + 1)
    arr = np.full((need,), pad_id, np.int32)
    arr[:total] = np.asarray(stream[:need], np.int32)[:min(total, need)]
    rows = arr.reshape(n_rows, seq_len + 1)

    tokens = rows[:, :-1].copy()
    labels = rows[:, 1:].copy()
    valid = np.zeros((need,), np.float32)
    valid[:min(total, need)] = 1.0
    # a position t is maskable if token t+1 starts a new doc (t is an eod)
    eod = np.zeros((need,), bool)
    idx = [b for b in bounds if b < need]
    eod[idx] = True
    vm = valid.reshape(n_rows, seq_len + 1)
    em = eod.reshape(n_rows, seq_len + 1)
    loss_mask = vm[:, 1:] * (1.0 - em[:, :-1].astype(np.float32))
    return {"tokens": tokens, "labels": labels, "loss_mask": loss_mask}


def make_packing_transform(seq_len: int, *, pad_id: int = 0, eod_id: int = 1):
    """Stager ``transform`` hook: raw shard (list/obj array of docs) ->
    packed batch dict."""
    def _tf(name: str, raw) -> Dict[str, np.ndarray]:
        if isinstance(raw, dict):   # already packed
            return raw
        docs = list(raw) if not isinstance(raw, np.ndarray) else (
            [raw] if raw.ndim == 1 else list(raw))
        return pack_documents(docs, seq_len, pad_id=pad_id, eod_id=eod_id)
    return _tf
