"""Asynchronous staging engine (the DDM transfer machinery).

Moves files ColdStore -> DiskCache on a worker pool, applying the
*on-demand transformation* at stage time (paper: "transform source data on
the storage side to the format optimal for delivery"), then announces
per-file availability on the bus (T_COLLECTION_UPDATED) — the signal that
drives the Transformer daemon's incremental dispatch.

Fault tolerance:
  * retries with exponential backoff on tape read errors (no backoff
    sleep after the final attempt — a terminal failure is marked, and
    announced, immediately);
  * hedged (duplicate) requests for stragglers: if a file's stage time
    exceeds ``hedge_factor`` x the observed median, a second request is
    issued and the first to land wins — classic tail-latency mitigation.

All timing (stage records, medians, deadlines) uses the monotonic
clock: a wall-clock step must not corrupt hedge decisions or expire a
``wait``.  The ``on_submitted`` / ``on_available`` / ``on_failed``
hooks let a DDM (see :class:`repro.carousel.ddm.CarouselDDM`) advance
and journal the per-file content state machine.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.carousel.storage import ColdStore, DiskCache
from repro.core import messaging as M
from repro.core.obs import RollingPercentile, get_logger

_log = get_logger("stager")


@dataclass
class StageRecord:
    name: str
    submitted: float             # monotonic
    finished: Optional[float] = None
    attempts: int = 0
    hedged: bool = False
    ok: bool = False


class Stager:
    # telemetry is optional: unbound, each hook costs one attribute
    # lookup against these class defaults
    _obs_stage_hist = None
    _obs_failures = None
    tracer = None

    def __init__(self, cold: ColdStore, cache: DiskCache,
                 bus: Optional[M.MessageBus] = None, *,
                 collection: str = "carousel",
                 workers: int = 4, max_attempts: int = 4,
                 backoff: float = 0.02, hedge_factor: float = 3.0,
                 hedge_min_samples: int = 8, latency_window: int = 512,
                 transform: Optional[Callable[[str, Any], Any]] = None,
                 on_available: Optional[Callable[[str], None]] = None,
                 on_failed: Optional[Callable[[str], None]] = None,
                 on_submitted: Optional[Callable[[str], None]] = None):
        self.cold = cold
        self.cache = cache
        self.bus = bus
        self.collection = collection
        self.transform = transform
        self.on_available = on_available
        self.on_failed = on_failed
        self.on_submitted = on_submitted
        self.max_attempts = max_attempts
        self.backoff = backoff
        self.hedge_factor = hedge_factor
        self.hedge_min_samples = hedge_min_samples
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix="stager")
        self._lock = threading.RLock()
        self.records: Dict[str, StageRecord] = {}
        self._landed: Dict[str, bool] = {}
        # rolling window: long-running stagers see millions of files,
        # and the median only needs the recent latency regime anyway.
        # RollingPercentile keeps a bisect-maintained sorted snapshot,
        # so the hedge tick reads the median in O(1) instead of
        # re-sorting the whole window every call.
        self._lat_window = RollingPercentile(window=latency_window)
        # landed (name, seconds) pairs awaiting drain_latencies()
        self._recent_latencies: List[Tuple[str, float]] = []
        self._futures: List[Future] = []
        self.hedges_issued = 0

    @property
    def _latencies(self) -> List[float]:
        """Arrival-ordered latency window (kept for introspection)."""
        return self._lat_window.values()

    # ------------------------------------------------------------------
    def bind_telemetry(self, registry, tracer=None) -> None:
        """Wire metrics/tracing (CarouselDDM forwards the head's)."""
        self._obs_stage_hist = registry.histogram(
            "stager_stage_seconds", "cold-to-cache staging latency",
            labels=("collection",)).labels(collection=self.collection)
        self._obs_failures = registry.counter(
            "stager_failures_total", "terminal staging failures",
            labels=("collection",)).labels(collection=self.collection)
        self.tracer = tracer

    def _median_latency(self) -> Optional[float]:
        if len(self._lat_window) < self.hedge_min_samples:
            return None
        return self._lat_window.median()

    def _land(self, name: str, data: Any, size: int) -> bool:
        """First landing wins (hedges make this racy by design)."""
        with self._lock:
            if self._landed.get(name):
                return False
            self._landed[name] = True
            rec = self.records[name]
            rec.finished = time.monotonic()
            rec.ok = True
            dt = rec.finished - rec.submitted
            attempts, hedged = rec.attempts, rec.hedged
            self._lat_window.observe(dt)
            self._recent_latencies.append((name, dt))
        if self._obs_stage_hist is not None:
            self._obs_stage_hist.observe(dt)
        self.cache.put(name, data, size, pin=False)
        # DDM state first, bus second: a consumer woken by the
        # announcement must observe the availability it announces
        if self.on_available is not None:
            self.on_available(name)
        if self.tracer is not None:
            self.tracer.emit("content_available",
                             collection=self.collection, entity=name,
                             data={"attempts": attempts, "hedged": hedged,
                                   "stage_s": round(dt, 6)})
        if self.bus is not None:
            self.bus.publish(M.T_COLLECTION_UPDATED,
                             {"collection": self.collection, "file": name})
        return True

    def _stage_once(self, name: str) -> None:
        rec = self.records[name]
        for attempt in range(1, self.max_attempts + 1):
            with self._lock:
                if self._landed.get(name):
                    return
                rec.attempts += 1
            try:
                raw = self.cold.read(name)
                data = (self.transform(name, raw)
                        if self.transform is not None else raw)
                size = self.cold.get(name).size
                self._land(name, data, size)
                return
            except IOError:
                if attempt < self.max_attempts:
                    # no sleep after the FINAL attempt: the record turns
                    # failed now, not one backoff interval later
                    time.sleep(self.backoff * (2 ** (attempt - 1)))
        # exhausted: only mark failed if nobody else landed it
        with self._lock:
            if self._landed.get(name):
                return
            rec.finished = time.monotonic()
            rec.ok = False
        _log.warning("staging failed terminally: %s/%s after %d attempts",
                     self.collection, name, rec.attempts)
        if self._obs_failures is not None:
            self._obs_failures.inc()
        if self.on_failed is not None:
            self.on_failed(name)
        if self.bus is not None:
            # announce terminal failure too, so pending fine-granularity
            # works re-evaluate completion instead of waiting forever
            self.bus.publish(M.T_COLLECTION_UPDATED,
                             {"collection": self.collection, "file": name,
                              "failed": True})

    def submit(self, name: str) -> None:
        with self._lock:
            if name in self.records:
                return
            self.records[name] = StageRecord(name, time.monotonic())
        if self.on_submitted is not None:
            self.on_submitted(name)
        if self.tracer is not None:
            self.tracer.emit("content_staging",
                             collection=self.collection, entity=name)
        self._futures.append(self._pool.submit(self._stage_once, name))

    def submit_all(self, names: List[str]) -> None:
        for n in names:
            self.submit(n)

    # -- straggler hedging (call periodically or via watch()) ---------------
    def hedge_check(self) -> int:
        med = self._median_latency()
        if med is None:
            return 0
        return self.hedge_overdue(self.hedge_factor * med)

    def hedge_overdue(self, threshold_s: float) -> int:
        """Re-submit every un-hedged in-flight file older than
        ``threshold_s``; first landing wins.  ``hedge_check`` derives
        the threshold from this stager's local median × hedge_factor;
        the Conductor calls this directly with the intelligence plane's
        learned staging p95 instead.  Either way a record hedges at
        most once, so repeated calls converge."""
        issued = 0
        now = time.monotonic()
        with self._lock:
            cands = [r for r in self.records.values()
                     if not r.finished and not r.hedged
                     and now - r.submitted > threshold_s]
            for r in cands:
                r.hedged = True
        for r in cands:
            self.hedges_issued += 1
            issued += 1
            self._futures.append(self._pool.submit(self._stage_once, r.name))
        return issued

    def drain_latencies(self) -> List[Tuple[str, float]]:
        """Landed ``(name, seconds)`` pairs since the last drain — the
        Conductor feeds these into the HistoryBook that learns the
        staging p95 it hedges against."""
        with self._lock:
            out, self._recent_latencies = self._recent_latencies, []
        return out

    def wait(self, timeout: float = 60.0,
             hedge_interval: float = 0.05) -> bool:
        """Block until every submitted file landed or terminally failed."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            self.hedge_check()
            with self._lock:
                pend = [r for r in self.records.values() if r.finished is None]
            if not pend:
                return True
            time.sleep(hedge_interval)
        return False

    def failed(self) -> List[str]:
        with self._lock:
            return [r.name for r in self.records.values()
                    if r.finished is not None and not r.ok]

    def shutdown(self) -> None:
        self._pool.shutdown(wait=False, cancel_futures=True)
