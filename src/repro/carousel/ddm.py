"""Production DDM implementation: collections backed by ColdStore +
DiskCache + Stager.  The iDDS Transformer daemon talks to this object;
``mark_processed`` implements the carousel's *prompt release* — the
moment every consumer of a file is done, its cache bytes are freed.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable

from repro.carousel.stager import Stager
from repro.carousel.storage import ColdStore, DiskCache
from repro.core.workflow import Collection, FileRef


class CarouselDDM:
    def __init__(self, cold: ColdStore, cache: DiskCache,
                 *, prompt_release: bool = True):
        self.cold = cold
        self.cache = cache
        self.prompt_release = prompt_release
        self._lock = threading.RLock()
        self._collections: Dict[str, Collection] = {}
        self._stagers: Dict[str, Stager] = {}

    def attach_stager(self, collection: str, stager: Stager) -> None:
        with self._lock:
            self._stagers[collection] = stager
        stager.on_available = lambda name: self.set_available(collection, name)

    def register_collection(self, name: str,
                            files: Iterable[FileRef]) -> Collection:
        with self._lock:
            c = Collection(name, files=list(files))
            self._collections[name] = c
            return c

    def register_from_cold(self, name: str) -> Collection:
        return self.register_collection(
            name, [FileRef(f.name, size=f.size, available=f.name in self.cache)
                   for f in self.cold.files()])

    def get_collection(self, name: str) -> Collection:
        with self._lock:
            if name not in self._collections:
                # output collections materialize lazily, initially empty
                self._collections[name] = Collection(name)
            return self._collections[name]

    def set_available(self, name: str, file_name: str,
                      available: bool = True) -> None:
        with self._lock:
            coll = self._collections[name]
            for f in coll.files:
                if f.name == file_name:
                    f.available = available
                    return
            # late-registered output content
            coll.files.append(FileRef(file_name, available=available))

    def mark_processed(self, name: str, file_name: str) -> None:
        with self._lock:
            for f in self._collections[name].files:
                if f.name == file_name:
                    f.processed = True
                    break
            else:
                raise KeyError(file_name)
        # the carousel's prompt release: free cache bytes immediately
        self.cache.release(file_name, drop=self.prompt_release)
