"""Production DDM implementation: collections backed by ColdStore +
DiskCache + Stager.  The iDDS Transformer daemon talks to this object;
``mark_processed`` implements the carousel's *prompt release* — the
moment every consumer of a file is done, its cache bytes are freed.

Mounted into a head service via ``IDDS(ddm=CarouselDDM(...))`` (or
``python -m repro.core.rest --carousel``): the head calls ``bind()`` at
construction, handing over its message bus and durable store, so every
content state transition (new -> staging -> available -> delivered |
failed) is announced on the bus (driving the Transformer's incremental
per-file dispatch) AND journaled through the store (so ``recover()``
rebuilds per-file delivery state after a crash).
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional

from repro.carousel.stager import Stager
from repro.carousel.storage import ColdStore, DiskCache
from repro.core import messaging as M
from repro.core.store import Store
from repro.core.workflow import Collection, FileRef


class CarouselDDM:
    def __init__(self, cold: ColdStore, cache: DiskCache,
                 *, prompt_release: bool = True):
        self.cold = cold
        self.cache = cache
        self.prompt_release = prompt_release
        self.bus: Optional[M.MessageBus] = None
        self.store: Optional[Store] = None
        self.metrics = None
        self.tracer = None
        self._lock = threading.RLock()
        self._collections: Dict[str, Collection] = {}
        self._stagers: Dict[str, Stager] = {}

    # ------------------------------------------------------------- wiring
    def bind(self, bus: Optional[M.MessageBus] = None,
             store: Optional[Store] = None) -> None:
        """Late-bind the head service's bus + store (``IDDS.__init__``
        calls this).  Already-attached stagers inherit the bus so their
        availability announcements reach the Transformer."""
        self.bus = bus
        self.store = store
        with self._lock:
            stagers = list(self._stagers.values())
        for st in stagers:
            if st.bus is None:
                st.bus = bus

    def bind_telemetry(self, metrics=None, tracer=None) -> None:
        """Late-bind the head's metrics registry + tracer (``IDDS``
        calls this right after :meth:`bind`); already-attached stagers
        pick them up too."""
        self.metrics = metrics
        self.tracer = tracer
        with self._lock:
            stagers = list(self._stagers.items())
        for _name, st in stagers:
            if metrics is not None:
                st.bind_telemetry(metrics, tracer)

    def _journal(self, collection: str, f: FileRef) -> None:
        if self.store is not None:
            self.store.save_contents(collection, [f.to_dict()])

    def _journal_collection(self, coll: Collection) -> None:
        if self.store is not None:
            self.store.save_collection(coll.to_dict())

    # ------------------------------------------------------------ stagers
    def attach_stager(self, collection: str, stager: Stager) -> None:
        with self._lock:
            self._stagers[collection] = stager
        stager.collection = collection
        if stager.bus is None:
            stager.bus = self.bus
        if self.metrics is not None:
            stager.bind_telemetry(self.metrics, self.tracer)
        stager.on_submitted = lambda name: self.mark_staging(collection,
                                                             name)
        stager.on_available = lambda name: self.set_available(collection,
                                                              name)
        stager.on_failed = lambda name: self.set_failed(collection, name)

    def stagers(self) -> List[Stager]:
        """Live stager snapshot — the Conductor's hedge pass walks
        these to drain landed latencies and issue learned-p95 hedges."""
        with self._lock:
            return list(self._stagers.values())

    def stage_collection(self, name: str, *,
                         stager: Optional[Stager] = None,
                         **stager_kwargs) -> Stager:
        """Start staging every not-yet-available file of ``name``: build
        (or adopt) a Stager wired to this DDM's bus/store hooks and
        submit the cold files.  Returns the stager (caller owns
        ``shutdown``, or leaves it to :meth:`shutdown`)."""
        coll = self.get_collection(name)
        if stager is None:
            stager = Stager(self.cold, self.cache, self.bus,
                            collection=name, **stager_kwargs)
        self.attach_stager(name, stager)
        with self._lock:
            todo = [f.name for f in coll.files if not f.available]
        stager.submit_all(todo)
        return stager

    def shutdown(self) -> None:
        with self._lock:
            stagers = list(self._stagers.values())
        for st in stagers:
            st.shutdown()

    # -------------------------------------------------------- collections
    def register_collection(self, name: str,
                            files: Iterable[FileRef]) -> Collection:
        with self._lock:
            c = Collection(name, files=list(files))
            self._collections[name] = c
        self._journal_collection(c)
        return c

    def register_from_cold(self, name: str) -> Collection:
        return self.register_collection(
            name, [FileRef(f.name, size=f.size, available=f.name in self.cache)
                   for f in self.cold.files()])

    def get_collection(self, name: str) -> Collection:
        with self._lock:
            if name not in self._collections:
                # output collections materialize lazily, initially empty
                self._collections[name] = Collection(name)
            return self._collections[name]

    def list_collections(self) -> List[str]:
        with self._lock:
            return list(self._collections)

    # ----------------------------------------------- content state machine
    def _find(self, name: str, file_name: str) -> Optional[FileRef]:
        for f in self.get_collection(name).files:
            if f.name == file_name:
                return f
        return None

    def mark_staging(self, name: str, file_name: str) -> None:
        with self._lock:
            f = self._find(name, file_name)
            if f is None or f.available or f.status == "failed":
                return
            f.set_status("staging")
        self._journal(name, f)

    def set_available(self, name: str, file_name: str,
                      available: bool = True) -> None:
        with self._lock:
            f = self._find(name, file_name)
            if f is None:
                # late-registered output content
                f = FileRef(file_name, available=available)
                self.get_collection(name).files.append(f)
            else:
                f.available = available
                f.set_status("available" if available else "new")
        self._journal(name, f)

    def set_failed(self, name: str, file_name: str) -> None:
        """Terminal staging failure (the Stager exhausted its attempts)."""
        with self._lock:
            f = self._find(name, file_name)
            if f is None:
                f = FileRef(file_name)
                self.get_collection(name).files.append(f)
            if f.available:
                return  # a hedge landed it; the failure lost the race
            f.set_status("failed")
        self._journal(name, f)

    def ensure_content(self, name: str, file_name: str,
                       size: int = 0) -> FileRef:
        with self._lock:
            f = self._find(name, file_name)
            if f is None:
                f = FileRef(file_name, size=size, available=True)
                self.get_collection(name).files.append(f)
            elif not f.available:
                f.available = True
                f.set_status("available")
        self._journal(name, f)
        return f

    def mark_processed(self, name: str, file_name: str) -> None:
        with self._lock:
            f = self._find(name, file_name)
            if f is None:
                raise KeyError(file_name)
            f.processed = True
            # input content delivered to (and consumed by) its processing
            f.set_status("delivered")
        self._journal(name, f)
        # the carousel's prompt release: free cache bytes immediately
        self.cache.release(file_name, drop=self.prompt_release)
