from repro.sharding.rules import (  # noqa: F401
    DEFAULT_RULES,
    ShardingRules,
    constrain,
    current_rules,
    param_shardings,
    param_specs,
    shard_params,
    use_rules,
)
