"""Logical-axis -> mesh-axis resolution with divisibility fallbacks.

Model code never names mesh axes directly; it tags tensor dims with
logical names ("batch", "heads", "ffn", ...).  A ``ShardingRules`` context
resolves those names against a concrete mesh, dropping any mapping whose
dimension is not divisible by the mesh-axis size (this is what makes the
40-head / 20-head / 6-head architectures shard cleanly: the "heads" rule
silently drops and the flattened "qkv" / "kv_seq" rules still apply).

Default physical mapping:

  batch   -> ("pod", "data")     activations' batch dim (DP across pods)
  embed   -> ("data",)           weight d_model dim (FSDP / ZeRO-3 style)
  heads   -> ("model",)          attention heads (TP)
  qkv     -> ("model",)          flattened q/k/v feature dim (TP)
  ffn     -> ("model",)          MLP hidden (TP)
  vocab   -> ("model",)          embedding/vocab rows (TP)
  expert  -> ("model",)          MoE experts (EP)
  kv_seq  -> ("model",)          KV sequence inside attention, ONLY for
                                 archs whose head count doesn't divide
                                 (flash-decoding-style partial softmax)
  layers  -> ()                  stacked-layer dim, never sharded
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import params as P

DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("data",),
    "heads": ("model",),
    "heads_ssm": ("model",),
    "ssm_p": ("model",),  # SSD head_dim fallback when heads don't divide
    "qkv": ("model",),
    "ffn": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "kv_seq": ("model",),
    "q_seq": ("model",),
    "layers": (),
    "seq": (),
}

_TLS = threading.local()


class ShardingRules:
    def __init__(self, mesh: Mesh,
                 rules: Optional[Dict[str, Tuple[str, ...]]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def axis_size(self, axes: Tuple[str, ...]) -> int:
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def resolve_dim(self, name: Optional[str], dim: int) -> Optional[Any]:
        """Mesh axes for one tensor dim, or None (replicated)."""
        if name is None:
            return None
        axes = tuple(a for a in self.rules.get(name, ())
                     if a in self.mesh.shape)
        if not axes:
            return None
        if dim % self.axis_size(axes) != 0:
            # divisibility fallback: try a prefix of the axes, else replicate
            for k in range(len(axes) - 1, 0, -1):
                sub = axes[:k]
                if dim % self.axis_size(sub) == 0:
                    return sub if len(sub) > 1 else sub[0]
            return None
        return axes if len(axes) > 1 else axes[0]

    def spec(self, logical: Sequence[Optional[str]],
             shape: Sequence[int]) -> PartitionSpec:
        """Resolve logical names, dropping duplicate mesh-axis uses (first
        dim wins) — lets e.g. MoE weights carry both "expert" and "ffn"
        logical tags and shard on whichever the arch's sizes allow."""
        assert len(logical) == len(shape), (logical, shape)
        resolved = []
        used: set = set()
        for n, d in zip(logical, shape):
            r = self.resolve_dim(n, d)
            if r is None:
                resolved.append(None)
                continue
            axes = r if isinstance(r, tuple) else (r,)
            if any(a in used for a in axes):
                resolved.append(None)
                continue
            used.update(axes)
            resolved.append(r)
        return PartitionSpec(*resolved)

    def sharding(self, logical, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, shape))


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_TLS, "rules", None)
    _TLS.rules = rules
    try:
        yield rules
    finally:
        _TLS.rules = prev


def current_rules() -> Optional[ShardingRules]:
    return getattr(_TLS, "rules", None)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical names; no-op without context."""
    r = current_rules()
    if r is None:
        return x
    return lax.with_sharding_constraint(x, r.sharding(logical, x.shape))


# ---------------------------------------------------------------------------
# Param-tree helpers
# ---------------------------------------------------------------------------


def param_specs(defs: Any, rules: ShardingRules) -> Any:
    """PartitionSpec tree for a ParamDef tree."""
    return P.tree_map(lambda d: rules.spec(d.logical, d.shape), defs)


def param_shardings(defs: Any, rules: ShardingRules) -> Any:
    return P.tree_map(lambda d: rules.sharding(d.logical, d.shape), defs)


def shard_params(arrs: Any, defs: Any, rules: ShardingRules) -> Any:
    """device_put a materialized param tree with its resolved shardings."""
    sh = param_shardings(defs, rules)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), arrs, sh)
