"""jit-able dispatch wrappers: Pallas TPU kernels vs pure-jnp XLA refs.

The model zoo calls these entry points exclusively.  ``use_pallas=False``
(CPU smoke tests, the 512-device dry-run) routes to ``ref.py``;
``use_pallas=True`` routes to the Pallas kernels (TPU target; validated on
CPU via interpret=True in tests).
"""
from __future__ import annotations



from repro.kernels import ref as _ref


def rmsnorm(x, w, *, eps: float = 1e-6, use_pallas: bool = False,
            interpret: bool = True):
    if use_pallas:
        from repro.kernels.rmsnorm import rmsnorm_pallas
        return rmsnorm_pallas(x, w, eps=eps, interpret=interpret)
    return _ref.rmsnorm_ref(x, w, eps)


def flash_attention(q, k, v, *, causal: bool = True, q_offset=0, kv_len=None,
                    sliding_window: int = 0, block_k: int = 512,
                    use_pallas: bool = False, interpret: bool = True,
                    carry_constrain=None, custom_vjp: bool = True):
    if use_pallas:
        from repro.kernels.flash_attention import flash_attention_pallas
        return flash_attention_pallas(
            q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
            sliding_window=sliding_window, interpret=interpret)
    return _ref.flash_attention_ref(
        q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
        sliding_window=sliding_window, block_k=block_k,
        carry_constrain=carry_constrain, custom_vjp=custom_vjp)


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128, init_state=None,
        return_state: bool = False, use_pallas: bool = False,
        interpret: bool = True):
    if use_pallas:
        from repro.kernels.ssd_scan import ssd_pallas
        return ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                          init_state=init_state, return_state=return_state,
                          interpret=interpret)
    return _ref.ssd_ref(x, dt, A, Bm, Cm, chunk=chunk,
                        init_state=init_state, return_state=return_state)


def ssd_decode(x, dt, A, Bm, Cm, h):
    """Single-token SSD recurrence (decode fast path)."""
    return _ref.ssd_decode_ref(x, dt, A, Bm, Cm, h)


def cross_entropy(hidden, w_vocab, targets, valid=None, *,
                  mode: str = "direct", block_v: int = 4096,
                  use_pallas: bool = False, interpret: bool = True):
    if use_pallas:
        from repro.kernels.cross_entropy import cross_entropy_pallas
        return cross_entropy_pallas(hidden, w_vocab, targets, valid,
                                    block_v=block_v, interpret=interpret)
    if mode == "blockwise":
        return _ref.cross_entropy_blockwise_ref(hidden, w_vocab, targets,
                                                valid, block_v=block_v)
    return _ref.cross_entropy_direct_ref(hidden, w_vocab, targets, valid)
