"""Flash-attention Pallas TPU kernel (forward).

TPU-native adaptation of the CUDA flash algorithm:
  * grid = (batch*q_heads, Sq/block_q, Sk/block_k); the KV dim is the
    innermost (sequential) grid axis, so the online-softmax running
    statistics (m, l) and the output accumulator live in VMEM scratch and
    persist across KV steps — the TPU analogue of a CUDA thread-block's
    shared-memory accumulators.
  * block shapes are MXU-aligned: (block_q, D) x (block_k, D) tiles with
    D = head_dim (128 on every assigned arch except whisper's 64).
  * GQA is handled in the BlockSpec index_map (q head -> kv head), so K/V
    tiles are fetched once per kv head group, not per q head repeat.
  * causal / sliding-window / cache-length masks are computed on the fly
    from iota — no mask tensor ever materializes.

Training uses kernels/ref.py (same math, custom O(S) VJP); this kernel is
the serving/prefill fast path and the per-shape validation target
(tests/test_kernels.py sweeps shapes x dtypes against the ref oracle).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, qo_ref, kl_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, sliding_window: int,
                  block_q: int, block_k: int, n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0].astype(jnp.float32)                  # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

    q_pos = qo_ref[0] + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kl_ref[0]
    if causal:
        mask = mask & (k_pos <= q_pos)
    if sliding_window:
        mask = mask & (k_pos > q_pos - sliding_window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc_scr[...] = (acc_scr[...] * alpha[:, None]
                    + jax.lax.dot(p.astype(v.dtype), v))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset=0,
    kv_len=None,
    sliding_window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    scale: Optional[float] = None,
    interpret: bool = True,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % max(Hkv, 1) == 0
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)

    # (B, H, S, D) layout: contiguous (S, D) tiles per (batch, head)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sqp, Skp = qt.shape[2], kt.shape[2]
    n_q, n_k = Sqp // block_q, Skp // block_k

    qt = qt.reshape(B * Hq, Sqp, D)
    kt = kt.reshape(B * Hkv, Skp, D)
    vt = vt.reshape(B * Hkv, Skp, D)

    qo = jnp.full((1,), q_offset, jnp.int32)
    kl = jnp.full((1,), Sk if kv_len is None else kv_len, jnp.int32)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        sliding_window=sliding_window, block_q=block_q, block_k=block_k,
        n_k=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda h, qi, ki, G=G: (h // G, ki, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda h, qi, ki, G=G: (h // G, ki, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, qo, kl)

    out = out.reshape(B, Hq, Sqp, D)[:, :, :Sq].transpose(0, 2, 1, 3)
    return out
