"""Mamba2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

The SSD insight — the recurrence factors into a block-diagonal intra-chunk
part (dense (Q,Q) matmuls, MXU food) plus a low-rank inter-chunk state
carry — maps directly onto a TPU grid:

  grid = (batch*heads, n_chunks), chunk dim innermost/sequential.
  Per step: load a (Q,P) x-tile + (Q,N) B/C tiles into VMEM, run the
  decay-weighted (Q,Q)@(Q,P) intra-chunk matmul, read/update the (P,N)
  running state held in VMEM scratch (persists across the chunk axis,
  like a flash-attention accumulator).

Q = chunk = 128 keeps every matmul MXU-shaped.  Zero-padding the tail is
algebraically safe: padded dt = 0 gives decay 1 and no state injection.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, h0_ref,
                y_ref, hout_ref, h_scr, *, H: int, n_c: int, chunk: int):
    bh = pl.program_id(0)
    ci = pl.program_id(1)
    h_idx = jax.lax.rem(bh, H)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)     # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)   # (Q,)
    Bm = B_ref[0, 0].astype(jnp.float32)    # (Q, N)
    Cm = C_ref[0, 0].astype(jnp.float32)    # (Q, N)
    A = A_ref[h_idx]                        # scalar (negative)

    dA = dt * A                             # (Q,)
    cs = jnp.cumsum(dA)                     # inclusive
    # intra-chunk decay matrix L[i,j] = exp(cs_i - cs_j) for j <= i
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = cs[:, None] - cs[None, :]
    L = jnp.where(j_idx <= i_idx, jnp.exp(seg), 0.0)

    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (Q, Q)
    M = CB * L * dt[None, :]
    y_intra = jax.lax.dot(M, x)                                  # (Q, P)

    h = h_scr[...]                                               # (P, N)
    y_inter = jax.lax.dot_general(Cm * jnp.exp(cs)[:, None], h,
                                  (((1,), (1,)), ((), ())))      # (Q, P)
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(sum dA) h + sum_s dt_s decay_end_s x_s B_s^T
    decay_end = jnp.exp(cs[-1] - cs)                             # (Q,)
    xw = x * (dt * decay_end)[:, None]                           # (Q, P)
    h_scr[...] = (h * jnp.exp(cs[-1])
                  + jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ()))))

    @pl.when(ci == n_c - 1)
    def _emit():
        hout_ref[0] = h_scr[...]


def ssd_pallas(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H) positive
    A: jax.Array,    # (H,) negative
    Bm: jax.Array,   # (B, S, G, N)
    Cm: jax.Array,   # (B, S, G, N)
    *,
    chunk: int = 128,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
    return_state: bool = False,
    interpret: bool = True,
):
    B_, S, H, P = x.shape
    _, _, G, N = Bm.shape
    assert H % G == 0
    HG = H // G
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    n_c = Sp // chunk

    xt = x.transpose(0, 2, 1, 3).reshape(B_ * H, n_c, chunk, P)
    dtt = dt.transpose(0, 2, 1).reshape(B_ * H, n_c, chunk)
    Bt = Bm.transpose(0, 2, 1, 3).reshape(B_ * G, n_c, chunk, N)
    Ct = Cm.transpose(0, 2, 1, 3).reshape(B_ * G, n_c, chunk, N)
    h0 = (jnp.zeros((B_ * H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32).reshape(B_ * H, P, N))

    def kv_map(bh, ci, H=H, HG=HG, G=G):
        return ((bh // H) * G + (bh % H) // HG, ci, 0, 0)

    kernel = functools.partial(_ssd_kernel, H=H, n_c=n_c, chunk=chunk)
    y, hout = pl.pallas_call(
        kernel,
        grid=(B_ * H, n_c),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bh, ci: (bh, ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, chunk, N), kv_map),
            pl.BlockSpec((1, 1, chunk, N), kv_map),
            pl.BlockSpec((1, P, N), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bh, ci: (bh, ci, 0, 0)),
            pl.BlockSpec((1, P, N), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_ * H, n_c, chunk, P), x.dtype),
            jax.ShapeDtypeStruct((B_ * H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), Bt, Ct, h0)

    y = y.reshape(B_, H, Sp, P).transpose(0, 2, 1, 3)[:, :S]
    if return_state:
        return y, hout.reshape(B_, H, P, N)
    return y
