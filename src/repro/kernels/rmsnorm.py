"""Fused RMSNorm Pallas TPU kernel.

One pass over the rows: each grid step loads a (block_rows, D) tile into
VMEM, computes the row-wise RMS statistic in f32 on the VPU, scales by the
(replicated) weight vector, and writes the normalized tile — no f32
intermediate ever round-trips to HBM (the XLA ref materializes x.astype
(f32) at CPU fusion boundaries).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)          # (bR, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                   block_rows: int = 256, interpret: bool = True
                   ) -> jax.Array:
    """x: (..., D); w: (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    bR = min(block_rows, R)
    pad = (-R) % bR
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    Rp = xf.shape[0]

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(Rp // bR,),
        in_specs=[
            pl.BlockSpec((bR, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bR, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, D), x.dtype),
        interpret=interpret,
    )(xf, w)
    return out[:R].reshape(orig_shape)
