"""Pure-jnp reference oracles for every Pallas kernel.

These are ALSO the XLA execution path used by the model zoo on CPU and in
the 512-device dry-run (Pallas targets TPU; ``interpret=True`` validates
the kernels against these functions in tests).

The attention reference is itself written flash-style (chunked online
softmax over KV blocks) so that (a) it is the mathematical oracle for the
Pallas kernel, and (b) the dry-run HLO never materializes a 32k x 32k
score matrix — HLO bytes reflect a production attention implementation.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with a custom VJP: math in f32, but inputs/outputs AND
    cotangents stay in the input dtype.  Without this, autodiff threads
    f32 cotangents through every residual/projection boundary — measured
    as ~2x the activation traffic and f32 (instead of bf16) tensor-
    parallel all-reduces in the backward pass (EXPERIMENTS.md §Perf).
    REPRO_RMSNORM_VJP=0 disables the custom VJP (debug escape hatch)."""
    import os
    if os.environ.get("REPRO_RMSNORM_VJP", "1") == "0":
        return _rmsnorm_fwd_math(x, w, eps)[0]
    return _rmsnorm_vjp(x, w, eps)


def _rmsnorm_fwd_math(x, w, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = lax.rsqrt(var + eps)
    y = xf * inv * w.astype(jnp.float32)
    return y.astype(x.dtype), inv


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_vjp(x, w, eps):
    return _rmsnorm_fwd_math(x, w, eps)[0]


def _rmsnorm_vjp_fwd(x, w, eps):
    y, inv = _rmsnorm_fwd_math(x, w, eps)
    return y, (x, w, inv)


def _rmsnorm_vjp_bwd(eps, res, g):
    x, w, inv = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    xhat = xf * inv
    gw = gf * wf
    dx = inv * (gw - xhat * jnp.mean(gw * xhat, axis=-1, keepdims=True))
    dw = jnp.sum(gf * xhat, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dw.astype(w.dtype)


_rmsnorm_vjp.defvjp(_rmsnorm_vjp_fwd, _rmsnorm_vjp_bwd)


# ---------------------------------------------------------------------------
# Flash attention (chunked online softmax), GQA, causal / sliding window,
# optional q position offset (decode) and non-causal (cross attention).
# ---------------------------------------------------------------------------


def _pad_to(x: jax.Array, axis: int, mult: int) -> Tuple[jax.Array, int]:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def flash_attention_ref(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    q_offset=0,  # scalar: absolute position of q[0] (decode)
    kv_len=None,  # scalar: #valid kv positions (cache may be longer)
    sliding_window: int = 0,
    block_k: int = 512,
    scale: Optional[float] = None,
    carry_constrain=None,  # optional sharding pin for the scan carry
    custom_vjp: bool = True,
) -> jax.Array:
    """Differentiable flash attention with an O(S) *custom* backward —
    autodiff through the online-softmax scan would stack per-block score
    residuals and reintroduce the O(S^2) memory this exists to avoid.
    ``custom_vjp=False`` keeps the naive-autodiff path (§Perf baseline)."""
    Sk = k.shape[1]
    qo = jnp.asarray(q_offset, jnp.int32)
    kl = jnp.asarray(Sk if kv_len is None else kv_len, jnp.int32)
    if not custom_vjp:
        out, _ = _flash_fwd_inner(
            q, k, v, qo, kl, causal=causal, sliding_window=sliding_window,
            block_k=block_k, scale=scale, carry_constrain=carry_constrain)
        return out
    fn = _flash_vjp_factory(bool(causal), int(sliding_window), int(block_k),
                            float(scale) if scale is not None else None,
                            carry_constrain)
    return fn(q, k, v, qo, kl)


def _flash_fwd_inner(
    q, k, v, q_offset, kv_len, *,
    causal, sliding_window, block_k, scale, carry_constrain,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % max(Hkv, 1) == 0, (Hq, Hkv)
    G = Hq // Hkv
    pin = carry_constrain if carry_constrain is not None else (lambda t: t)
    scale = scale if scale is not None else D ** -0.5
    block_k = min(block_k, max(Sk, 1))

    k, _ = _pad_to(k, 1, block_k)
    v, _ = _pad_to(v, 1, block_k)
    Skp = k.shape[1]
    n_blocks = Skp // block_k

    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, G, D)
    kf = k.astype(jnp.float32).reshape(B, Skp, Hkv, D)
    vf = v.astype(jnp.float32).reshape(B, Skp, Hkv, D)

    q_pos = q_offset + jnp.arange(Sq)  # (Sq,)
    valid_len = Sk if kv_len is None else kv_len

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, j = blk  # kb/vb: (B, block_k, Hkv, D)
        k_pos = j * block_k + jnp.arange(block_k)
        # scores: (B, Sq, Hkv, G, block_k)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kb)
        mask = k_pos[None, :] < valid_len  # (1, block_k) padded/cache tail
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if sliding_window:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - sliding_window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = pin(jnp.maximum(m, s.max(axis=-1)).reshape(B, Sq, Hkv * G)
                    ).reshape(B, Sq, Hkv, G)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = pin((l * alpha + p.sum(axis=-1)).reshape(B, Sq, Hkv * G)
                    ).reshape(B, Sq, Hkv, G)
        acc_new = (acc * alpha[..., None]
                   + jnp.einsum("bqhgk,bkhd->bqhgd", p, vb))
        acc_new = pin(acc_new.reshape(B, Sq, Hkv * G, D)
                      ).reshape(B, Sq, Hkv, G, D)
        return (m_new, l_new, acc_new), None

    m0 = pin(jnp.full((B, Sq, Hkv * G), NEG_INF, jnp.float32)
             ).reshape(B, Sq, Hkv, G)
    l0 = pin(jnp.zeros((B, Sq, Hkv * G), jnp.float32)).reshape(B, Sq, Hkv, G)
    acc0 = pin(jnp.zeros((B, Sq, Hkv * G, D), jnp.float32)
               ).reshape(B, Sq, Hkv, G, D)

    kb = kf.reshape(B, n_blocks, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = vf.reshape(B, n_blocks, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)
    (m, l, acc), _ = lax.scan(
        body, (m0, l0, acc0), (kb, vb, jnp.arange(n_blocks))
    )
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).reshape(B, Sq, Hq, D)
    lse = m + jnp.log(l)  # (B, Sq, Hkv, G)
    return out.astype(q.dtype), lse


def _flash_bwd_inner(
    q, k, v, q_offset, kv_len, out, lse, dout, *,
    causal, sliding_window, block_k, scale, carry_constrain,
):
    """Flash backward: per-block recompute of p; O(Sq + Sk) residuals."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    pin = carry_constrain if carry_constrain is not None else (lambda t: t)
    scale = scale if scale is not None else D ** -0.5
    block_k = min(block_k, max(Sk, 1))

    kp, _ = _pad_to(k, 1, block_k)
    vp, _ = _pad_to(v, 1, block_k)
    Skp = kp.shape[1]
    n_blocks = Skp // block_k

    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, G, D)
    kf = kp.astype(jnp.float32).reshape(B, Skp, Hkv, D)
    vf = vp.astype(jnp.float32).reshape(B, Skp, Hkv, D)
    dof = dout.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    of = out.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    # delta_i = sum_d dout_i * out_i  (rowsum trick)
    delta = jnp.sum(dof * of, axis=-1)  # (B, Sq, Hkv, G)

    q_pos = q_offset + jnp.arange(Sq)
    valid_len = kv_len

    kb_all = kf.reshape(B, n_blocks, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb_all = vf.reshape(B, n_blocks, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)

    def body(dq_acc, blk):
        kb, vb, j = blk
        k_pos = j * block_k + jnp.arange(block_k)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kb)
        mask = k_pos[None, :] < valid_len
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if sliding_window:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - sliding_window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # (B, Sq, Hkv, G, bk)
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        dv_b = jnp.einsum("bqhgk,bqhgd->bkhd", p, dof)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", dof, vb)
        ds = p * (dp - delta[..., None])  # (B, Sq, Hkv, G, bk)
        dq_new = dq_acc + jnp.einsum("bqhgk,bkhd->bqhgd", ds, kb)
        dq_new = pin(dq_new.reshape(B, Sq, Hkv * G, D)
                     ).reshape(B, Sq, Hkv, G, D)
        dk_b = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qf)
        return dq_new, (dk_b, dv_b)

    dq0 = pin(jnp.zeros((B, Sq, Hkv * G, D), jnp.float32)
              ).reshape(B, Sq, Hkv, G, D)
    dq, (dk_blocks, dv_blocks) = lax.scan(
        body, dq0, (kb_all, vb_all, jnp.arange(n_blocks)))
    dq = (dq * scale).reshape(B, Sq, Hq, D).astype(q.dtype)
    dk = dk_blocks.transpose(1, 0, 2, 3, 4).reshape(B, Skp, Hkv, D)[:, :Sk]
    dv = dv_blocks.transpose(1, 0, 2, 3, 4).reshape(B, Skp, Hkv, D)[:, :Sk]
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


@functools.lru_cache(maxsize=None)
def _flash_vjp_factory(causal, sliding_window, block_k, scale,
                       carry_constrain):
    import numpy as _np
    _f0 = lambda: _np.zeros((), jax.dtypes.float0)

    @jax.custom_vjp
    def fa(q, k, v, q_offset, kv_len):
        out, _ = _flash_fwd_inner(
            q, k, v, q_offset, kv_len, causal=causal,
            sliding_window=sliding_window, block_k=block_k, scale=scale,
            carry_constrain=carry_constrain)
        return out

    def fa_fwd(q, k, v, q_offset, kv_len):
        out, lse = _flash_fwd_inner(
            q, k, v, q_offset, kv_len, causal=causal,
            sliding_window=sliding_window, block_k=block_k, scale=scale,
            carry_constrain=carry_constrain)
        return out, (q, k, v, q_offset, kv_len, out, lse)

    def fa_bwd(res, dout):
        q, k, v, q_offset, kv_len, out, lse = res
        dq, dk, dv = _flash_bwd_inner(
            q, k, v, q_offset, kv_len, out, lse, dout, causal=causal,
            sliding_window=sliding_window, block_k=block_k, scale=scale,
            carry_constrain=carry_constrain)
        return dq, dk, dv, _f0(), _f0()

    fa.defvjp(fa_fwd, fa_bwd)
    return fa


def attention_naive(q, k, v, *, causal=True, q_offset=0, kv_len=None,
                    sliding_window: int = 0, scale=None):
    """O(Sq*Sk) direct attention — oracle for the oracle (tiny shapes only)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if kv_len is not None:
        mask = mask & (k_pos[None, :] < kv_len)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if sliding_window:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - sliding_window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality) — chunked scan.
# ---------------------------------------------------------------------------


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., Q). Returns (..., Q, Q) with out[..., i, j] = sum_{j<s<=i} x[s]
    for j <= i, -inf otherwise (log of the decay matrix L)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_ref(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)   (post-softplus, positive)
    A: jax.Array,   # (H,)        (negative)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    *,
    chunk: int = 128,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
    return_state: bool = False,
):
    """Chunked SSD: y[t] = C[t] . h[t],
    h[t] = exp(dt[t] A) h[t-1] + dt[t] B[t] x[t].

    Heads H are grouped over G B/C groups (H % G == 0).
    """
    B_, S, H, P = x.shape
    _, _, G, N = Bm.shape
    assert H % G == 0
    HG = H // G
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    C_ = Sp // chunk

    f32 = jnp.float32
    xc = x.astype(f32).reshape(B_, C_, chunk, H, P)
    dtc = dt.astype(f32).reshape(B_, C_, chunk, H)
    Bc = Bm.astype(f32).reshape(B_, C_, chunk, G, N)
    Cc = Cm.astype(f32).reshape(B_, C_, chunk, G, N)
    Af = A.astype(f32)

    dA = dtc * Af[None, None, None, :]            # (B, C, Q, H)
    dA_cs = jnp.cumsum(dA, axis=2)                # cumulative within chunk

    # ---- intra-chunk (diagonal blocks) ----
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (B, C, H, Q, Q)
    # scores: C[l] . B[s] per head group
    CB = jnp.einsum("bclgn,bcsgn->bcgls", Cc, Bc)   # (B, C, G, Q, Q)
    CB = jnp.repeat(CB, HG, axis=2)                  # (B, C, H, Q, Q)
    M = CB * L                                       # decay-weighted
    y_intra = jnp.einsum("bchls,bcsh,bcshp->bclhp", M, dtc, xc)

    # ---- chunk states ----
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (B, C, Q, H)
    Br = jnp.repeat(Bc, HG, axis=3)                       # (B, C, Q, H, N)
    states = jnp.einsum("bcshn,bcsh,bcsh,bcshp->bchpn",
                        Br, decay_to_end, dtc, xc)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))  # (B, C, H)

    def scan_fn(h, inp):
        st, dec = inp  # st: (B, H, P, N), dec: (B, H)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    h0 = (jnp.zeros((B_, H, P, N), f32) if init_state is None
          else init_state.astype(f32))
    states_t = states.transpose(1, 0, 2, 3, 4)        # (C, B, H, P, N)
    decay_t = chunk_decay.transpose(1, 0, 2)          # (C, B, H)
    h_last, h_prev = lax.scan(scan_fn, h0, (states_t, decay_t))
    # (B, C, H, P, N) state BEFORE chunk
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)

    # ---- inter-chunk output ----
    in_decay = jnp.exp(dA_cs)                         # (B, C, Q, H)
    Cr = jnp.repeat(Cc, HG, axis=3)                   # (B, C, Q, H, N)
    y_inter = jnp.einsum("bclhn,bclh,bchpn->bclhp", Cr, in_decay, h_prev)

    y = (y_intra + y_inter).reshape(B_, Sp, H, P)[:, :S]
    y = y.astype(x.dtype)
    if return_state:
        return y, h_last.astype(f32)
    return y


def ssd_decode_ref(
    x: jax.Array,   # (B, H, P)  one token
    dt: jax.Array,  # (B, H)
    A: jax.Array,   # (H,)
    Bm: jax.Array,  # (B, G, N)
    Cm: jax.Array,  # (B, G, N)
    h: jax.Array,   # (B, H, P, N) state
):
    f32 = jnp.float32
    B_, H, P = x.shape
    G = Bm.shape[1]
    HG = H // G
    dA = jnp.exp(dt.astype(f32) * A.astype(f32)[None, :])  # (B, H)
    Br = jnp.repeat(Bm.astype(f32), HG, axis=1)  # (B, H, N)
    Cr = jnp.repeat(Cm.astype(f32), HG, axis=1)
    h_new = h * dA[:, :, None, None] + (
        dt.astype(f32)[:, :, None, None]
        * x.astype(f32)[:, :, :, None]
        * Br[:, :, None, :]
    )
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Cr)
    return y.astype(x.dtype), h_new


def ssd_sequential_ref(x, dt, A, Bm, Cm, *, init_state=None):
    """Token-by-token recurrence — oracle for ssd_ref (tiny shapes only)."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    h = (jnp.zeros((B_, H, P, N), jnp.float32) if init_state is None
         else init_state.astype(jnp.float32))
    ys = []
    for t in range(S):
        y, h = ssd_decode_ref(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        ys.append(y)
    return jnp.stack(ys, axis=1).astype(x.dtype), h


# ---------------------------------------------------------------------------
# Cross entropy: direct (oracle) and vocab-blockwise (never materializes the
# full logit row per token beyond one block).
# ---------------------------------------------------------------------------


def cross_entropy_direct_ref(
    hidden: jax.Array,    # (T, D)
    w_vocab: jax.Array,   # (V, D)
    targets: jax.Array,   # (T,) int32
    valid: Optional[jax.Array] = None,  # (T,) bool
):
    logits = jnp.einsum("td,vd->tv", hidden.astype(jnp.float32),
                        w_vocab.astype(jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    nll = lse - tgt
    if valid is not None:
        nll = jnp.where(valid, nll, 0.0)
        return nll.sum() / jnp.maximum(valid.sum(), 1)
    return nll.mean()


def cross_entropy_blockwise_ref(
    hidden: jax.Array,
    w_vocab: jax.Array,
    targets: jax.Array,
    valid: Optional[jax.Array] = None,
    *,
    block_v: int = 2048,
):
    T, D = hidden.shape
    V = w_vocab.shape[0]
    block_v = min(block_v, V)
    wp, _ = _pad_to(w_vocab, 0, block_v)
    Vp = wp.shape[0]
    nb = Vp // block_v
    hf = hidden.astype(jnp.float32)
    wb = wp.astype(jnp.float32).reshape(nb, block_v, D)

    def body(carry, blk):
        m, l, tgt = carry
        w_blk, j = blk
        logits = hf @ w_blk.T  # (T, block_v)
        vids = j * block_v + jnp.arange(block_v)
        logits = jnp.where(vids[None, :] < V, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        l_new = (l * jnp.exp(m - m_new)
                 + jnp.exp(logits - m_new[:, None]).sum(-1))
        hit = vids[None, :] == targets[:, None]
        tgt_new = tgt + jnp.where(hit, logits, 0.0).sum(-1) \
            + jnp.where(hit.any(-1), 0.0, 0.0)
        return (m_new, l_new, tgt_new), None

    m0 = jnp.full((T,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((T,), jnp.float32)
    t0 = jnp.zeros((T,), jnp.float32)
    (m, l, tgt), _ = lax.scan(body, (m0, l0, t0), (wb, jnp.arange(nb)))
    nll = (m + jnp.log(l)) - tgt
    if valid is not None:
        nll = jnp.where(valid, nll, 0.0)
        return nll.sum() / jnp.maximum(valid.sum(), 1)
    return nll.mean()
