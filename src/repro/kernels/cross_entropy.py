"""Vocab-blockwise cross-entropy Pallas TPU kernel (forward).

Never materializes a (T, V) logit row block beyond (block_t, block_v):
grid = (T/block_t, V/block_v) with the vocab axis innermost; running
(max, sumexp, target-logit) statistics live in VMEM scratch across vocab
steps.  At 152k vocab this is the difference between 64 MB and 2.5 GB of
logits per device batch (see train/loss.py for the custom-VJP XLA twin
used in training).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ce_kernel(h_ref, w_ref, t_ref, nll_ref, m_scr, l_scr, tgt_scr, *,
               block_t: int, block_v: int, n_v: int, V: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        tgt_scr[...] = jnp.zeros_like(tgt_scr)

    h = h_ref[...].astype(jnp.float32)          # (bT, D)
    w = w_ref[...].astype(jnp.float32)          # (bV, D)
    logits = jax.lax.dot_general(h, w, (((1,), (1,)), ((), ())))  # (bT,bV)

    vids = vi * block_v + jax.lax.broadcasted_iota(
        jnp.int32, (block_t, block_v), 1)
    logits = jnp.where(vids < V, logits, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    l_scr[...] = (l_scr[...] * jnp.exp(m_prev - m_new)
                  + jnp.exp(logits - m_new[:, None]).sum(axis=-1))
    m_scr[...] = m_new

    tgt = t_ref[...]                            # (bT,) int32
    hit = vids == tgt[:, None]
    tgt_scr[...] = tgt_scr[...] + jnp.where(hit, logits, 0.0).sum(axis=-1)

    @pl.when(vi == n_v - 1)
    def _emit():
        lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        nll_ref[...] = lse - tgt_scr[...]


def cross_entropy_pallas(
    hidden: jax.Array,    # (T, D)
    w_vocab: jax.Array,   # (V, D)
    targets: jax.Array,   # (T,) int32
    valid=None,           # (T,) float/bool or None
    *,
    block_t: int = 256,
    block_v: int = 2048,
    interpret: bool = True,
):
    T, D = hidden.shape
    V = w_vocab.shape[0]
    block_t = min(block_t, T)
    block_v = min(block_v, V)
    pad_t = (-T) % block_t
    pad_v = (-V) % block_v
    h = jnp.pad(hidden, ((0, pad_t), (0, 0))) if pad_t else hidden
    w = jnp.pad(w_vocab, ((0, pad_v), (0, 0))) if pad_v else w_vocab
    t = jnp.pad(targets, (0, pad_t)) if pad_t else targets
    Tp, Vp = h.shape[0], w.shape[0]
    n_t, n_v = Tp // block_t, Vp // block_v

    nll = pl.pallas_call(
        functools.partial(_ce_kernel, block_t=block_t, block_v=block_v,
                          n_v=n_v, V=V),
        grid=(n_t, n_v),
        in_specs=[
            pl.BlockSpec((block_t, D), lambda ti, vi: (ti, 0)),
            pl.BlockSpec((block_v, D), lambda ti, vi: (vi, 0)),
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
        out_shape=jax.ShapeDtypeStruct((Tp,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t,), jnp.float32),
            pltpu.VMEM((block_t,), jnp.float32),
            pltpu.VMEM((block_t,), jnp.float32),
        ],
        interpret=interpret,
    )(h, w, t.astype(jnp.int32))[:T]

    if valid is not None:
        v = valid.astype(jnp.float32)
        return (nll * v).sum() / jnp.maximum(v.sum(), 1.0)
    return nll.mean()
