"""qwen3-moe-235b-a22b — MoE, 94L d_model=4096 64H (GQA kv=4) per-expert
d_ff=1536 vocab=151936, 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]

128 experts / 16-way model axis = 8 experts per shard (EP on `model`).
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    num_experts=128,
    num_experts_per_tok=8,
    moe_capacity_factor=1.25,
    qkv_bias=False,
    gated_mlp=True,
    act="silu",
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)

SMOKE = CONFIG.replace(
    name="qwen3-moe-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab_size=256,
    num_experts=8,
    num_experts_per_tok=2,
)

register(CONFIG, SMOKE)
