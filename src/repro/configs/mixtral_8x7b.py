"""mixtral-8x7b — MoE, 32L d_model=4096 32H (GQA kv=8) per-expert
d_ff=14336 vocab=32000, 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    num_experts_per_tok=2,
    moe_capacity_factor=1.25,
    sliding_window=4096,
    gated_mlp=True,
    act="silu",
    rope_theta=1_000_000.0,
    norm_eps=1e-5,
    source="arXiv:2401.04088; hf",
)

SMOKE = CONFIG.replace(
    name="mixtral-8x7b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    num_experts=4,
    num_experts_per_tok=2,
    sliding_window=64,
)

register(CONFIG, SMOKE)
