"""llava-next-mistral-7b — VLM, mistral-7b text backbone:
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The vision tower + anyres tiling is a STUB per the assignment:
``input_specs()`` provides precomputed, already-projected patch
embeddings (B, num_img_patches, d_model) which are prepended to the text
embedding sequence.  2880 patches ~= anyres 2x2+base grid of 576-patch
CLIP tiles.  long_500k skipped (full attention).
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_img_patches=2880,
    gated_mlp=True,
    act="silu",
    rope_theta=1_000_000.0,
    norm_eps=1e-5,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)

SMOKE = CONFIG.replace(
    name="llava-next-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    num_img_patches=16,
)

register(CONFIG, SMOKE)
