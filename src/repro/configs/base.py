"""Model / shape / run configuration for iDDS-JAX.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig`` entries.  Configs are plain
dataclasses so they serialize trivially (the iDDS client/server boundary
round-trips them through JSON).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass
class ModelConfig:
    name: str
    family: str  # dense | ssm | hybrid | moe | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention details ---
    qkv_bias: bool = False
    mlp_bias: bool = False
    gated_mlp: bool = True  # SwiGLU-style (llama family); False -> plain MLP
    act: str = "silu"  # silu | gelu
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 = full attention

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4

    # --- hybrid (zamba2-style): one attention block every `attn_every`
    # ssm layers; 0 = not hybrid ---
    attn_every: int = 0

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_frames: int = 1500  # fixed mel-frame count after conv frontend

    # --- VLM (llava): anyres patch embeddings prepended to the sequence ---
    num_img_patches: int = 0

    # --- numerics ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    # --- serving ---
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8

    # --- provenance ---
    source: str = ""

    def __post_init__(self) -> None:
        if self.head_dim == 0 and self.num_heads:
            self.head_dim = self.d_model // self.num_heads
        if self.family == "ssm":
            self.attn_every = 0

    # Derived quantities -----------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ModelConfig":
        return cls(**json.loads(s))

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shape configuration (assigned input shapes)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Run configuration (training hyperparameters, parallelism knobs)
# ---------------------------------------------------------------------------


@dataclass
class RunConfig:
    """Knobs for a concrete (arch x shape x mesh) lowering/run."""

    accum_steps: int = 1  # gradient-accumulation microbatches
    remat: str = "full"  # none | full | dots
    scan_layers: bool = True
    use_pallas: bool = False  # CPU dry-run/smoke uses the XLA ref path
    grad_compression: str = "none"  # none | bf16
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1_000
    max_grad_norm: float = 1.0
    seed: int = 0
    attn_block_k: int = 512  # flash-style chunk for the XLA ref path
    attn_block_q: int = 0  # 0 = no q chunking
    ce_mode: str = "blockwise"  # blockwise (custom-VJP, O(T*D) mem) | direct
    ce_block_v: int = 8192
    ce_dtype: str = "bfloat16"  # logits matmul input dtype (f32 accum)
    moe_impl: str = "shardmap"  # shardmap (explicit EP) | gspmd (auto)
    flash_custom_vjp: bool = True  # False = autodiff through the scan
    #   (baseline: stacks per-block score residuals, O(S^2) memory)
    logits_in_fp32: bool = True
    # §Perf levers
    fuse_qkv: bool = True
    opt_state_dtype: str = "float32"  # float32 | bfloat16 (compression)

    def replace(self, **kw: Any) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}
_SMOKE_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE_REGISTRY[cfg.name] = smoke
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _SMOKE_REGISTRY[name]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # importing the arch modules populates the registry
    from repro.configs import archs  # noqa: F401


# Which (arch, shape) cells are runnable; the rest are documented skips.
PURE_ATTENTION_FAMILIES = ("dense", "moe", "encdec", "vlm")


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Return (runnable, reason-if-skipped) for an (arch x shape) cell."""
    if shape.name == "long_500k" and cfg.family in PURE_ATTENTION_FAMILIES:
        return False, (
            "long_500k requires sub-quadratic attention / bounded state; "
            f"{cfg.name} is pure full-attention (see DESIGN.md skip list)"
        )
    return True, ""


def all_cells() -> List[Tuple[str, str, bool, str]]:
    """Every (arch, shape) pair with runnability flag + skip reason."""
    _ensure_loaded()
    out = []
    for arch in list_archs():
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, why = cell_is_runnable(cfg, shape)
            out.append((arch, sname, ok, why))
    return out
