from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    all_cells,
    cell_is_runnable,
    get_config,
    get_smoke_config,
    list_archs,
)
