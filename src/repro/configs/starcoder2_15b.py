"""starcoder2-15b — dense, 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, GQA + RoPE, non-gated GELU MLP with biases. [arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    qkv_bias=True,
    mlp_bias=True,
    gated_mlp=False,
    act="gelu",
    rope_theta=100_000.0,
    norm_eps=1e-5,
    source="arXiv:2402.19173; hf",
)

SMOKE = CONFIG.replace(
    name="starcoder2-15b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
)

register(CONFIG, SMOKE)
