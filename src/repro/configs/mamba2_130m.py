"""mamba2-130m — attention-free SSM (SSD, state-space duality),
24L d_model=768 vocab=50280 ssm_state=128. [arXiv:2405.21060; unverified]

d_inner = 2*768 = 1536; ssd heads = 1536/64 = 24.  Runs long_500k:
decode state is O(1) in sequence length.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    ssm_conv=4,
    norm_eps=1e-5,
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)

SMOKE = CONFIG.replace(
    name="mamba2-130m-smoke",
    num_layers=2,
    d_model=64,
    vocab_size=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=32,
)

register(CONFIG, SMOKE)
