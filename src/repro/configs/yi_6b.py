"""yi-6b — dense, 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA. [arXiv:2403.04652; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    qkv_bias=False,
    gated_mlp=True,
    act="silu",
    rope_theta=5_000_000.0,
    norm_eps=1e-5,
    source="arXiv:2403.04652; hf",
)

SMOKE = CONFIG.replace(
    name="yi-6b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
)

register(CONFIG, SMOKE)
