"""whisper-tiny — encoder-decoder audio transformer backbone,
4L(enc)+4L(dec) d_model=384 6H d_ff=1536 vocab=51865.
[arXiv:2212.04356; unverified]

The conv mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, encoder_frames, d_model).
6 heads not divisible by 16 -> attention falls back to replicated-head /
flattened-dim sharding (the model is tiny; MLP still shards).
long_500k skipped (full attention).
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,
    encoder_layers=4,
    encoder_frames=1500,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    qkv_bias=True,
    mlp_bias=True,
    gated_mlp=False,
    act="gelu",
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
    norm_eps=1e-5,
    source="arXiv:2212.04356; unverified",
)

SMOKE = CONFIG.replace(
    name="whisper-tiny-smoke",
    num_layers=2,
    encoder_layers=2,
    encoder_frames=32,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
)

register(CONFIG, SMOKE)
