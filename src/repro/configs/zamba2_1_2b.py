"""zamba2-1.2b — hybrid Mamba2 + shared attention blocks,
38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000 ssm_state=64.
[arXiv:2411.15242; hf]

Adaptation note (DESIGN.md §6): Zamba2 interleaves Mamba2 blocks with a
*shared* attention block applied every ~6 layers over concatenated
embeddings.  We realize the same compute/communication pattern as a
hybrid stack: Mamba2 layers with one (weight-shared) attention+MLP block
applied every `attn_every` SSM layers.  Runs long_500k: SSM state is O(1)
and only the periodic attention blocks hold (sharded) 500k KV.
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    ssm_conv=4,
    attn_every=6,
    gated_mlp=True,
    act="gelu",
    rope_theta=10_000.0,
    norm_eps=1e-5,
    source="arXiv:2411.15242; hf",
)

SMOKE = CONFIG.replace(
    name="zamba2-1.2b-smoke",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=32,
    attn_every=2,
)

register(CONFIG, SMOKE)
