"""Import every assigned architecture config, populating the registry."""
from repro.configs import (  # noqa: F401
    qwen1_5_32b,
    yi_6b,
    qwen1_5_4b,
    starcoder2_15b,
    mamba2_130m,
    zamba2_1_2b,
    qwen3_moe_235b,
    mixtral_8x7b,
    whisper_tiny,
    llava_next_mistral_7b,
)
