"""qwen1.5-4b — dense, 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]

20 heads not divisible by 16 -> flattened-QKV / KV-seq sharding fallback.
decode_32k uses the int8 KV cache (kv=20 => 1.7 TB bf16).
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    gated_mlp=True,
    act="silu",
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
    kv_cache_dtype="int8",
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)

SMOKE = CONFIG.replace(
    name="qwen1.5-4b-smoke",
    num_layers=2,
    d_model=48,
    num_heads=3,
    num_kv_heads=3,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    kv_cache_dtype="bfloat16",
)

register(CONFIG, SMOKE)
