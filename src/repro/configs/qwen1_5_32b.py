"""qwen1.5-32b — dense, 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]

40 heads is NOT divisible by the 16-way model axis: the sharding rules
fall back to flattened-QKV-dim sharding for the projections (5120 % 16 == 0)
and KV-sequence sharding inside attention (see sharding/rules.py).
decode_32k uses the int8 KV cache (MHA kv=40 => 5.5 TB bf16 > pod HBM).
"""
from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    gated_mlp=True,
    act="silu",
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
    kv_cache_dtype="int8",
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)

SMOKE = CONFIG.replace(
    name="qwen1.5-32b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    kv_cache_dtype="bfloat16",
)

register(CONFIG, SMOKE)
