"""Intelligence plane: the history + locality brain of the dispatch path.

The paper's fourth iDDS function is the "intelligent" part — applying
data-locality and delivery-history knowledge to orchestrate delivery
rather than dispatching blindly.  This module is that brain, packaged
as a pluggable :class:`IntelPlane` the mechanical planes consult:

* :class:`HistoryBook` — per-queue EWMA job latency and completion /
  failure tallies plus a sliding window of per-file staging latencies
  (the learned p95 the Conductor hedges against).  Dirty rows are
  journaled through the store's ``stats`` table so a restarted head
  starts warm instead of re-learning from scratch.
* :class:`AffinityIndex` — worker_id → held-content map built from the
  cache manifests workers volunteer on heartbeat, scored at lease time
  so jobs land where their inputs already live.
* :class:`IntelPlane` — the bundle the scheduler, Conductor and
  Watchdog share, plus plain counters (affinity hits/misses, aging
  promotions, hedges, rescores) surfaced via ``GET /v1/intel``.

Everything here is advisory: with the plane unplugged (``--intel off``,
the default) the scheduler's legacy FIFO-within-priority path runs
bit-exact and nothing below is imported on the hot path.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Set, Tuple

from repro.core.obs import RollingPercentile

__all__ = ["HistoryBook", "AffinityIndex", "IntelPlane"]


class HistoryBook:
    """EWMA latency + completion-rate history, journaled as stats rows.

    One record per queue: exponentially weighted mean job duration and
    monotone completed/failed tallies.  The completion rate is Laplace
    smoothed — ``(ok + 1) / (ok + failed + 2)`` — so a queue with no
    history scores a neutral 0.5 instead of dividing by zero, and one
    early failure does not condemn the queue forever.

    Staging latencies feed a :class:`RollingPercentile` window per
    collection; :meth:`staging_p95` is the learned hedge threshold that
    replaces the stager-local ``hedge_factor`` guess once enough
    samples have landed.
    """

    def __init__(self, *, alpha: float = 0.25, staging_window: int = 512,
                 min_staging_samples: int = 8):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = float(alpha)
        self.min_staging_samples = int(min_staging_samples)
        self._staging_window = int(staging_window)
        self._lock = threading.Lock()
        # queue -> {"ewma_s", "completed", "failed"}
        self._queues: Dict[str, Dict[str, float]] = {}
        # collection -> exact sliding window of observed staging times
        self._staging: Dict[str, RollingPercentile] = {}
        # collection -> monotone count of samples ever observed
        self._staged: Dict[str, int] = {}
        self._dirty: Set[str] = set()

    # -- recording ----------------------------------------------------

    def record_job(self, queue: str, duration_s: Optional[float],
                   ok: bool = True) -> None:
        with self._lock:
            rec = self._queues.setdefault(
                queue, {"ewma_s": 0.0, "completed": 0, "failed": 0})
            if ok:
                rec["completed"] += 1
            else:
                rec["failed"] += 1
            if duration_s is not None and duration_s >= 0.0:
                prev = rec["ewma_s"]
                rec["ewma_s"] = (duration_s if prev == 0.0 else
                                 prev + self.alpha * (duration_s - prev))
            self._dirty.add(queue)

    def record_staging(self, collection: str, duration_s: float) -> None:
        with self._lock:
            win = self._staging.get(collection)
            if win is None:
                win = self._staging[collection] = RollingPercentile(
                    window=self._staging_window)
            win.observe(duration_s)
            self._staged[collection] = self._staged.get(collection, 0) + 1

    # -- queries ------------------------------------------------------

    def completion_rate(self, queue: str) -> float:
        with self._lock:
            rec = self._queues.get(queue)
            if rec is None:
                return 0.5
            ok, bad = rec["completed"], rec["failed"]
        return (ok + 1.0) / (ok + bad + 2.0)

    def samples(self, queue: str) -> int:
        with self._lock:
            rec = self._queues.get(queue)
            return int(rec["completed"] + rec["failed"]) if rec else 0

    def ewma_latency(self, queue: str) -> Optional[float]:
        with self._lock:
            rec = self._queues.get(queue)
            return rec["ewma_s"] if rec and rec["ewma_s"] > 0.0 else None

    def staging_p95(self, collection: str) -> Optional[float]:
        """The learned hedge threshold, or None until the window holds
        at least ``min_staging_samples`` observations."""
        with self._lock:
            win = self._staging.get(collection)
        if win is None or len(win) < self.min_staging_samples:
            return None
        return win.percentile(95)

    # -- persistence --------------------------------------------------

    def flush_dirty(self) -> List[Dict[str, Any]]:
        """Stats rows for queues touched since the last flush, in the
        store's ``save_stats`` shape.  Staging windows are deliberately
        not journaled: they are transfer-rate observations of the
        currently mounted media, stale the moment the head restarts."""
        now = time.time()
        with self._lock:
            rows = [{"scope": "queue", "key": q,
                     "data": dict(self._queues[q]), "updated_at": now}
                    for q in sorted(self._dirty) if q in self._queues]
            self._dirty.clear()
        return rows

    def load(self, rows: Iterable[Dict[str, Any]]) -> int:
        """Warm-start from journaled stats rows (inverse of
        :meth:`flush_dirty`); unknown scopes are ignored."""
        n = 0
        with self._lock:
            for row in rows or ():
                if row.get("scope") != "queue":
                    continue
                data = row.get("data") or {}
                self._queues[str(row.get("key"))] = {
                    "ewma_s": float(data.get("ewma_s", 0.0)),
                    "completed": int(data.get("completed", 0)),
                    "failed": int(data.get("failed", 0))}
                n += 1
        return n

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            queues = {q: dict(rec) for q, rec in self._queues.items()}
            staging = {c: {"samples": self._staged.get(c, 0),
                           "window": len(win),
                           "p95_s": (win.percentile(95)
                                     if len(win) >= self.min_staging_samples
                                     else None)}
                       for c, win in self._staging.items()}
        for q, rec in queues.items():
            ok, bad = rec["completed"], rec["failed"]
            rec["completion_rate"] = round(
                (ok + 1.0) / (ok + bad + 2.0), 4)
        return {"queues": queues, "staging": staging}


class AffinityIndex:
    """worker_id → held-content names, built from heartbeat manifests.

    Entries expire ``ttl`` seconds after the worker's last manifest so
    a dead worker's cache stops attracting jobs.  All timestamps are
    caller-supplied (the scheduler passes its own injectable clock), so
    the index itself is clock-free and trivially testable.
    """

    def __init__(self, *, ttl: float = 300.0):
        self.ttl = float(ttl)
        self._lock = threading.Lock()
        self._held: Dict[str, Set[str]] = {}
        self._seen: Dict[str, float] = {}

    def update(self, worker_id: str, names: Iterable[str],
               now: float) -> None:
        """Replace the worker's manifest (workers report their whole
        cache each heartbeat, so this is idempotent, not additive)."""
        manifest = {str(n) for n in names}
        with self._lock:
            self._held[worker_id] = manifest
            self._seen[worker_id] = now

    def score(self, worker_id: str, names: Iterable[str],
              now: float) -> int:
        """How many of ``names`` the worker already holds (0 if the
        manifest expired)."""
        with self._lock:
            seen = self._seen.get(worker_id)
            if seen is None or now - seen > self.ttl:
                return 0
            held = self._held.get(worker_id)
            if not held:
                return 0
            return sum(1 for n in names if n in held)

    def forget(self, worker_id: str) -> None:
        with self._lock:
            self._held.pop(worker_id, None)
            self._seen.pop(worker_id, None)

    def prune(self, now: float) -> int:
        with self._lock:
            stale = [w for w, t in self._seen.items()
                     if now - t > self.ttl]
            for w in stale:
                self._held.pop(w, None)
                self._seen.pop(w, None)
        return len(stale)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {w: len(names) for w, names in self._held.items()}


class IntelPlane:
    """The pluggable bundle consumed across the dispatch path.

    * the scheduler scores lease candidates with :attr:`affinity` and
      :attr:`history` and ages waiting jobs every
      :attr:`aging_interval` seconds of wait (+1 effective priority —
      the starvation-proof term: any affinity or completion-rate edge
      is a tie-break *within* an effective-priority level, so a waiting
      job eventually outranks a perpetually-refilled favored queue);
    * the Conductor hedges staging that exceeds ``hedge_headroom`` ×
      the learned p95;
    * the Watchdog rescores queue priorities from completion rates
      once ``min_rescore_samples`` outcomes have been observed.

    Counters are plain ints guarded by the owner's locks (exposed via
    ``/v1/intel`` and mirrored into the metrics registry by whichever
    plane increments them).
    """

    def __init__(self, *, aging_interval: float = 30.0,
                 scan_width: int = 8, affinity_ttl: float = 300.0,
                 hedge_headroom: float = 1.5,
                 min_rescore_samples: int = 20,
                 history: Optional[HistoryBook] = None):
        if aging_interval <= 0.0:
            raise ValueError("aging_interval must be positive")
        if scan_width < 1:
            raise ValueError("scan_width must be >= 1")
        self.aging_interval = float(aging_interval)
        self.scan_width = int(scan_width)
        self.hedge_headroom = float(hedge_headroom)
        self.min_rescore_samples = int(min_rescore_samples)
        self.history = history if history is not None else HistoryBook()
        self.affinity = AffinityIndex(ttl=affinity_ttl)
        # plain tallies; incremented under the consuming plane's lock
        self.affinity_hits = 0
        self.affinity_misses = 0
        self.aging_promotions = 0
        self.hedges_issued = 0
        self.rescores = 0

    def affinity_hit_rate(self) -> Optional[float]:
        total = self.affinity_hits + self.affinity_misses
        return (self.affinity_hits / total) if total else None

    def rescore_boost(self, queue: str) -> int:
        """Priority adjustment from observed completion rate: queues
        that mostly fail are deprioritized one level so healthy queues
        drain first; near-perfect queues get one level of boost.  The
        magnitude is deliberately ±1 — aging adds a level every
        ``aging_interval`` seconds, so a rescore can never starve."""
        if self.history.samples(queue) < self.min_rescore_samples:
            return 0
        rate = self.history.completion_rate(queue)
        if rate < 0.5:
            return -1
        if rate >= 0.95:
            return 1
        return 0

    def snapshot(self) -> Dict[str, Any]:
        hit_rate = self.affinity_hit_rate()
        return {
            "enabled": True,
            "aging_interval_s": self.aging_interval,
            "scan_width": self.scan_width,
            "hedge_headroom": self.hedge_headroom,
            "affinity": {
                "workers": self.affinity.snapshot(),
                "hits": self.affinity_hits,
                "misses": self.affinity_misses,
                "hit_rate": (round(hit_rate, 4)
                             if hit_rate is not None else None),
            },
            "aging_promotions": self.aging_promotions,
            "hedges_issued": self.hedges_issued,
            "rescores": self.rescores,
            "history": self.history.snapshot(),
        }
