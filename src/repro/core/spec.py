"""Declarative workflow authoring: the ``WorkflowSpec`` builder.

Hand-wiring ``add_template`` / ``add_condition`` / ``add_initial`` calls
spreads one logical edge across three statements and leaks the DG's
internal vocabulary (templates, branches, triggers) into every client.
``WorkflowSpec`` is the fluent authoring surface the examples and
services build on instead — it produces exactly the same JSON-
serializable :class:`~repro.core.workflow.Workflow`, so nothing changes
on the wire or in the daemons:

    spec = WorkflowSpec("quickstart")
    reco = spec.work("reco", payload="reconstruct")
    spec.work("sim", payload="simulate") \\
        .when("good_quality", then=[(reco, "pass_events")]) \\
        .start({"n_events": 800}) \\
        .start({"n_events": 200})
    wf = spec.build()

Vocabulary:

  ``spec.work(name, payload, ...)``  declare a work template; returns a
                                     chainable :class:`WorkStep`.
  ``step.start(params)``             mark an initial Work instance
                                     (repeatable for fan-out).
  ``step.then(target, ...)``         unconditional successor edge;
                                     returns the *target* step so
                                     pipelines read left-to-right:
                                     ``a.then(b).then(c)``.
  ``step.when(predicate, then=..., otherwise=...)``
                                     conditional edge (the DG's
                                     Condition); returns *self* so one
                                     step can carry several conditions.

Branch targets are ``WorkStep`` objects, template-name strings, or
``(target, binder_name)`` pairs when the edge re-binds parameters.
Cycles are legal (that is what ``max_iterations`` bounds) — this is a
DG builder, not a DAG builder.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Tuple, Union

from repro.core.workflow import (Branch, Condition, Workflow,
                                 WorkTemplate)

# a branch target: a step, a template name, or (target, binder)
Target = Union["WorkStep", str, Tuple[Union["WorkStep", str], str]]


class WorkStep:
    """One declared work template, chainable into edges."""

    def __init__(self, spec: "WorkflowSpec", template: WorkTemplate):
        self._spec = spec
        self._template = template

    @property
    def name(self) -> str:
        return self._template.name

    def start(self, params: Optional[Dict[str, Any]] = None) -> "WorkStep":
        """Add an initial Work instance bound to ``params``.  Call
        repeatedly to fan out (one Work per call)."""
        self._spec._initial.append((self.name, dict(params or {})))
        return self

    def then(self, target: Target, *, binder: str = "identity",
             max_iterations: int = 100) -> "WorkStep":
        """Unconditional successor: when a Work of this step terminates,
        instantiate ``target``.  Returns the target step so pipelines
        chain: ``a.then(b).then(c)``."""
        self.when("always", then=[_with_binder(target, binder)],
                  max_iterations=max_iterations)
        return self._spec._resolve(target)

    def when(self, predicate: str, *, then: Iterable[Target] = (),
             otherwise: Iterable[Target] = (), binder: str = "identity",
             max_iterations: int = 100) -> "WorkStep":
        """Conditional successors: evaluate ``predicate`` against this
        step's terminated Works; satisfied -> instantiate every target
        in ``then``, else every target in ``otherwise``.  Returns
        *self* so a step can stack multiple conditions."""
        self._spec._conditions.append(Condition(
            trigger=self.name, predicate=predicate,
            true_next=self._spec._branches(then, binder),
            false_next=self._spec._branches(otherwise, binder),
            max_iterations=max_iterations))
        return self


def _with_binder(target: Target, binder: str) -> Target:
    if binder == "identity" or isinstance(target, tuple):
        return target
    return (target, binder)


class WorkflowSpec:
    """Declarative builder producing a plain :class:`Workflow`."""

    def __init__(self, name: str):
        self.name = name
        self._templates: Dict[str, WorkTemplate] = {}
        self._conditions: List[Condition] = []
        self._initial: List[Tuple[str, Dict[str, Any]]] = []

    # -- declaration -------------------------------------------------------
    def work(self, name: str, payload: str, *,
             defaults: Optional[Dict[str, Any]] = None,
             input_collection: Optional[str] = None,
             output_collection: Optional[str] = None,
             granularity: str = "fine",
             max_attempts: int = 3,
             start: Optional[Union[Dict[str, Any],
                                   Iterable[Dict[str, Any]]]] = None,
             ) -> WorkStep:
        """Declare a work template.  ``start=`` is shorthand for
        ``.start(...)`` — pass one params dict, or a list of dicts for
        fan-out."""
        if name in self._templates:
            raise ValueError(f"work {name!r} declared twice")
        t = WorkTemplate(
            name=name, payload=payload, defaults=dict(defaults or {}),
            input_collection=input_collection,
            output_collection=output_collection,
            granularity=granularity, max_attempts=max_attempts)
        self._templates[name] = t
        step = WorkStep(self, t)
        if start is not None:
            for params in ([start] if isinstance(start, dict) else start):
                step.start(params)
        return step

    # -- assembly ----------------------------------------------------------
    def build(self) -> Workflow:
        """Validate and assemble the Workflow (same JSON shape as the
        hand-wired API — submit it exactly as before)."""
        wf = Workflow(name=self.name)
        for t in self._templates.values():
            wf.add_template(t)
        for c in self._conditions:
            wf.add_condition(c)  # validates trigger + branch targets
        for template, params in self._initial:
            wf.add_initial(template, params)
        return wf

    # -- internals ---------------------------------------------------------
    def _resolve(self, target: Target) -> WorkStep:
        if isinstance(target, tuple):
            target = target[0]
        if isinstance(target, WorkStep):
            if target._spec is not self:
                raise ValueError(
                    f"work {target.name!r} belongs to another spec")
            return target
        if target not in self._templates:
            raise KeyError(f"unknown work {target!r}; declare it with "
                           f"spec.work(...) first")
        return WorkStep(self, self._templates[target])

    def _branches(self, targets: Union[Target, Iterable[Target]],
                  binder: str) -> List[Branch]:
        if isinstance(targets, (WorkStep, str)) or (
                isinstance(targets, tuple) and len(targets) == 2
                and isinstance(targets[1], str)
                and isinstance(targets[0], (WorkStep, str))):
            targets = [targets]  # single target passed bare
        out = []
        for t in targets:
            b = binder
            if isinstance(t, tuple):
                t, b = t
            out.append(Branch(self._resolve(t).name, binder=b))
        return out
