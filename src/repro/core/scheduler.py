"""Distributed execution plane, head side: lease-based job scheduling.

The paper's iDDS never executes payloads itself — a workflow-management
system (PanDA) with *pull-based pilots* on grid sites does the
processing.  This module is that boundary for the reproduction:

  * :class:`JobScheduler` — a priority job queue with lease-based
    dispatch.  Workers lease jobs (``POST /jobs/lease``), renew their
    lease with heartbeats while executing, and report the outcome; a
    lease that is not renewed before its deadline expires and the job is
    requeued automatically, consuming an attempt exactly as the
    Carrier's retry path would.  Deadlines use the monotonic clock so
    wall-clock jumps can neither kill nor immortalize a lease; the lease
    table is journaled through the :class:`~repro.core.store.Store` so
    ``IDDS.recover()`` can requeue leases orphaned by a head crash.
  * :class:`DistributedWFM` — a :class:`~repro.core.daemons.WFMExecutor`
    whose "grid sites" are remote worker processes (``python -m
    repro.worker``) pulling over the REST gateway.  ``IDDS(executor=
    DistributedWFM())`` switches the Carrier from in-process execution
    to distributed dispatch without touching daemon logic.

Priority and routing ride on the Processing's params: ``priority``
(higher leases first, default 0) and ``queue`` (default ``"default"``).
Per-queue throttling caps bound how many leases a queue may have
outstanding at once.

With an intelligence plane plugged in (``enable_intel``, see
``repro.core.intel``), dispatch is scored instead of FIFO: candidates
compete on (effective priority, input-affinity hits against the
worker's reported cache manifest, queue completion rate, FIFO order),
where effective priority = base + Watchdog rescore boost + one level
per ``aging_interval`` seconds waited.  The aging term is the
starvation proof: affinity and completion rate only reorder *within*
an effective-priority level, and every waiting job climbs one level
per interval, so it eventually outranks any perpetually-refilled
favored queue.  With no plane attached (the default) the legacy path
runs unchanged.
"""
from __future__ import annotations

import heapq
import threading
import time
import uuid
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.daemons import WFMExecutor
from repro.core.store import Store
from repro.core.workflow import Processing, ProcessingStatus

_PENDING, _LEASED, _DONE = "pending", "leased", "done"
# fenced by a `suspend` lifecycle command: not leasable until resumed
_SUSPENDED = "suspended"


class SchedulerConflict(Exception):
    """Lease validation failed (stale worker, expired lease, unknown
    job).  The scheduler's state did not change; the REST layer maps
    this to a 409 envelope."""


class _Lease:
    __slots__ = ("lease_id", "worker_id", "deadline", "ttl", "granted")

    def __init__(self, worker_id: str, deadline: float, ttl: float,
                 granted: float = 0.0):
        self.lease_id = f"lease-{uuid.uuid4().hex[:12]}"
        self.worker_id = worker_id
        self.deadline = deadline
        self.ttl = ttl
        self.granted = granted  # scheduler clock: job-duration metric


class _Job:
    __slots__ = ("proc", "queue", "priority", "attempt", "state", "lease",
                 "seq", "outcome", "completed_by", "lease_key", "enqueued")

    def __init__(self, proc: Processing, queue: str, priority: int,
                 seq: int):
        self.proc = proc
        self.queue = queue
        self.priority = priority
        self.attempt = proc.attempt
        self.state = _PENDING
        self.lease: Optional[_Lease] = None
        self.lease_key: Optional[str] = None  # idempotency key, if any
        self.seq = seq
        self.enqueued = 0.0  # scheduler clock at last _push (aging term)
        # (status, result, error, attempt) once terminal from the
        # scheduler's point of view; consumed by DistributedWFM.poll
        self.outcome: Optional[Tuple[str, Any, Optional[str], int]] = None
        self.completed_by: Optional[str] = None


class JobScheduler:
    """Priority job queue with lease-based dispatch (head side).

    Thread-safe: REST threads lease/heartbeat/complete while the
    Carrier thread enqueues and polls outcomes.  Never takes any lock
    other than its own (callers must not hold ``Context.lock`` when
    calling in — the stat hook takes it).
    """

    def __init__(self, *, default_ttl: float = 30.0, max_ttl: float = 300.0,
                 queue_caps: Optional[Dict[str, int]] = None,
                 worker_ttl: float = 60.0, retain_done: int = 4096,
                 clock: Optional[Callable[[], float]] = None):
        self.default_ttl = default_ttl
        self.max_ttl = max_ttl
        self.queue_caps = dict(queue_caps or {})
        self.worker_ttl = worker_ttl
        self._clock = clock if clock is not None else time.monotonic
        self._lock = threading.RLock()
        self._jobs: Dict[str, _Job] = {}
        self._heaps: Dict[str, List[Tuple[int, int, str]]] = {}
        self._deadlines: List[Tuple[float, str, str]] = []  # (dl, lease, job)
        self._queue_active: Dict[str, int] = {}
        self._workers: Dict[str, Dict[str, Any]] = {}
        # idempotency key -> job_ids leased under it (n>1 for multi-lease)
        self._lease_keys: Dict[str, List[str]] = {}
        self._done_ring: deque = deque()
        self._retain_done = retain_done
        self._next_worker_prune = self._clock() + worker_ttl
        self._seq = 0
        self._draining = False
        self._store: Optional[Store] = None
        self._on_stat: Optional[Callable[[str, int], None]] = None
        # intelligence plane (None = legacy FIFO dispatch, bit-exact)
        self._intel: Any = None
        self._queue_boost: Dict[str, int] = {}  # Watchdog rescore output

    # -- telemetry (class attrs: unbound costs one attribute lookup;
    # per-verb children cached at attach so the hot verbs skip the
    # labels() key build) -----
    _obs_op = None
    _obs_lease = None
    _obs_heartbeat = None
    _obs_complete = None
    _obs_job_dur = None
    _obs_intel = None
    _on_event = None
    _metrics = None

    # ------------------------------------------------------------- wiring
    def attach(self, store: Store,
               on_stat: Optional[Callable[..., None]] = None,
               metrics: Any = None,
               on_event: Optional[Callable[..., None]] = None) -> None:
        """Bind the head service's store (lease journaling), stats hook,
        metrics registry and trace-event hook; called by
        ``DistributedWFM.attach`` from ``IDDS``.  ``on_event(event,
        proc_id, data)`` fires outside the scheduler lock for
        ``job_leased`` / ``job_completed``."""
        self._store = store
        self._on_stat = on_stat
        self._on_event = on_event
        if metrics is not None:
            self._obs_op = metrics.histogram(
                "scheduler_op_seconds", "scheduler verb latency",
                labels=("op",))
            self._obs_lease = self._obs_op.labels(op="lease")
            self._obs_heartbeat = self._obs_op.labels(op="heartbeat")
            self._obs_complete = self._obs_op.labels(op="complete")
            self._obs_job_dur = metrics.histogram(
                "scheduler_job_seconds",
                "job duration, lease grant to completion "
                "report").labels()
            self._metrics = metrics
            if self._intel is not None:
                self._bind_intel_metrics(metrics)

    def enable_intel(self, intel: Any = None) -> Any:
        """Plug in the intelligence plane (an
        ``repro.core.intel.IntelPlane``; a default one is built when
        None).  With no plane attached — the default — every dispatch
        path is the legacy FIFO-within-priority behavior, bit for bit."""
        if intel is None:
            from repro.core.intel import IntelPlane
            intel = IntelPlane()
        with self._lock:
            self._intel = intel
        if self._metrics is not None:
            self._bind_intel_metrics(self._metrics)
        return intel

    @property
    def intel(self) -> Any:
        return self._intel

    def _bind_intel_metrics(self, metrics: Any) -> None:
        fam = metrics.counter(
            "scheduler_intel_events_total",
            "intelligence-plane scheduling events",
            labels=("kind",))
        self._obs_intel = {
            "affinity_hit": fam.labels(kind="affinity_hit"),
            "affinity_miss": fam.labels(kind="affinity_miss"),
            "aging_promotion": fam.labels(kind="aging_promotion"),
            "queue_rescore": fam.labels(kind="queue_rescore"),
        }

    def _bump(self, key: str, n: int = 1) -> None:
        if self._on_stat is not None:
            self._on_stat(key, n)

    @staticmethod
    def _lease_journal_row(job: _Job) -> Dict[str, Any]:
        return {
            "job_id": job.proc.proc_id,
            "lease_id": job.lease.lease_id,
            "worker_id": job.lease.worker_id,
            "queue": job.queue,
            "attempt": job.attempt,
            "ttl": job.lease.ttl,
            # wall clock: a restarted head cannot compare old monotonic
            # values, and recovery treats every journaled lease as
            # orphaned anyway — this is operator-facing metadata
            "expires_at": time.time() + job.lease.ttl,
        }

    def _journal_lease(self, job: _Job) -> None:
        if self._store is None or job.lease is None:
            return
        self._store.save_lease(self._lease_journal_row(job))

    def _journal_leases(self, jobs: List[_Job]) -> None:
        """One journal commit for a whole batch of grants/renewals."""
        if self._store is None:
            return
        rows = [self._lease_journal_row(j) for j in jobs
                if j.lease is not None]
        if rows:
            self._store.save_leases_bulk(rows)

    def _drop_lease_row(self, job_id: str) -> None:
        if self._store is not None:
            self._store.delete_lease(job_id)

    # ------------------------------------------------------------ enqueue
    def enqueue(self, proc: Processing) -> None:
        """Register a Processing for dispatch.  Idempotent per proc_id:
        a re-submission (Carrier retry, crash recovery) resets the job
        to pending with the Processing's current attempt count; any
        live lease is revoked (the stale worker's report gets a 409)."""
        queue = str(proc.params.get("queue", "default"))
        priority = int(proc.params.get("priority", 0))
        with self._lock:
            job = self._jobs.get(proc.proc_id)
            if job is None:
                self._seq += 1
                job = _Job(proc, queue, priority, self._seq)
                self._jobs[proc.proc_id] = job
            else:
                if job.state == _PENDING:
                    return  # duplicate announcement
                if job.state == _LEASED:
                    self._release_lease(job)
                job.proc = proc
                job.attempt = proc.attempt
                job.outcome = None
                job.completed_by = None
                job.state = _PENDING
                self._seq += 1
                job.seq = self._seq
            self._push(job)
            self._bump("jobs_queued")

    def _push(self, job: _Job) -> None:
        job.state = _PENDING
        job.lease = None
        job.enqueued = self._clock()
        heapq.heappush(self._heaps.setdefault(job.queue, []),
                       (-job.priority, job.seq, job.proc.proc_id))

    # -------------------------------------------------------------- lease
    def lease(self, worker_id: str, *, queues: Optional[List[str]] = None,
              ttl: Optional[float] = None,
              idempotency_key: Optional[str] = None,
              manifest: Optional[List[str]] = None) -> Optional[Dict]:
        """Hand the highest-priority pending job to ``worker_id`` under a
        new lease, or return None if nothing is dispatchable (empty
        queues, throttling caps, draining).  ``idempotency_key`` makes a
        client retry safe: a repeated key while the resulting lease is
        still held returns the same job instead of leasing a second
        one.  ``manifest`` (the worker's held-content names) refreshes
        the affinity index before scoring when intel is on."""
        jobs = self.lease_many(worker_id, n=1, queues=queues, ttl=ttl,
                               idempotency_key=idempotency_key,
                               manifest=manifest)
        return jobs[0] if jobs else None

    def lease_many(self, worker_id: str, *, n: int = 1,
                   queues: Optional[List[str]] = None,
                   ttl: Optional[float] = None,
                   idempotency_key: Optional[str] = None,
                   manifest: Optional[List[str]] = None) -> List[Dict]:
        """Lease up to ``n`` jobs in ONE lock acquisition and ONE journal
        commit (`POST /jobs/lease?n=`).  Returns [] when nothing is
        dispatchable; fewer than ``n`` when the queues run dry.  A
        repeated ``idempotency_key`` replays the payloads of the jobs
        from the original grant that this worker still holds —
        regardless of any ``manifest``/affinity change between the
        retries (the replay is keyed on the grant, not re-scored)."""
        obs = self._obs_lease
        t0 = time.monotonic() if obs is not None else 0.0
        out = self._lease_many_impl(worker_id, n=n, queues=queues,
                                    ttl=ttl,
                                    idempotency_key=idempotency_key,
                                    manifest=manifest)
        if obs is not None:
            obs.observe(time.monotonic() - t0)
        if self._on_event is not None:
            for p in out:
                self._on_event("job_leased", p["job_id"],
                               {"worker_id": worker_id,
                                "queue": p["queue"],
                                "attempt": p["attempt"]})
        return out

    def _lease_many_impl(self, worker_id: str, *, n: int = 1,
                         queues: Optional[List[str]] = None,
                         ttl: Optional[float] = None,
                         idempotency_key: Optional[str] = None,
                         manifest: Optional[List[str]] = None
                         ) -> List[Dict]:
        if not worker_id:
            raise ValueError("worker_id is required")
        n = int(n)
        if n < 1:
            raise ValueError("n must be >= 1")
        ttl = self.default_ttl if ttl is None else min(float(ttl),
                                                       self.max_ttl)
        if ttl <= 0:
            raise ValueError("lease_ttl must be positive")
        now = self._clock()
        with self._lock:
            self._expire_locked(now)
            self._touch_worker(worker_id)
            if manifest is not None and self._intel is not None:
                self._intel.affinity.update(worker_id, manifest, now)
            if self._draining:
                return []
            if idempotency_key:
                jids = self._lease_keys.get(idempotency_key)
                if jids is not None:
                    replay = []
                    for jid in jids:
                        job = self._jobs.get(jid)
                        if (job is not None and job.state == _LEASED
                                and job.lease.worker_id == worker_id):
                            replay.append(self._job_payload(job))
                    if replay:
                        return replay  # replayed (possibly partial) grant
            leased: List[_Job] = []
            while len(leased) < n:
                job = (self._pop_best(queues) if self._intel is None
                       else self._pop_best_intel(queues, worker_id, now))
                if job is None:
                    break
                job.state = _LEASED
                job.lease = _Lease(worker_id, now + ttl, ttl,
                                   granted=now)
                job.proc.status = ProcessingStatus.RUNNING
                self._queue_active[job.queue] = (
                    self._queue_active.get(job.queue, 0) + 1)
                heapq.heappush(self._deadlines,
                               (job.lease.deadline, job.lease.lease_id,
                                job.proc.proc_id))
                self._workers[worker_id]["active_leases"] += 1
                leased.append(job)
            if not leased:
                return []
            if idempotency_key:
                self._lease_keys[idempotency_key] = [
                    j.proc.proc_id for j in leased]
                for job in leased:
                    job.lease_key = idempotency_key
            self._journal_leases(leased)
            self._bump("jobs_leased", len(leased))
            return [self._job_payload(j) for j in leased]

    def _pop_best(self, queues: Optional[List[str]]) -> Optional[_Job]:
        allowed = list(queues) if queues else list(self._heaps)
        best: Optional[_Job] = None
        best_q: Optional[str] = None
        for q in allowed:
            heap = self._heaps.get(q)
            if not heap:
                continue
            cap = self.queue_caps.get(q)
            if cap is not None and self._queue_active.get(q, 0) >= cap:
                continue  # throttled: queue at its outstanding-lease cap
            # lazy deletion: skip entries whose job moved on (re-enqueue
            # with a newer seq, completion, revoked lease)
            while heap:
                neg_pr, seq, jid = heap[0]
                job = self._jobs.get(jid)
                if (job is None or job.state != _PENDING
                        or job.seq != seq or job.queue != q):
                    heapq.heappop(heap)
                    continue
                break
            if not heap:
                continue
            neg_pr, seq, jid = heap[0]
            job = self._jobs[jid]
            # best across queues: highest priority, then oldest seq
            if best is None or (neg_pr, seq) < (-best.priority, best.seq):
                best, best_q = job, q
        if best is None:
            return None
        heapq.heappop(self._heaps[best_q])
        return best

    def _pop_best_intel(self, queues: Optional[List[str]],
                        worker_id: str, now: float) -> Optional[_Job]:
        """Scored dispatch (intelligence plane attached).  Examines up
        to ``scan_width`` live head candidates per eligible queue —
        heaps only order their head, so deeper inspection means popping
        — and picks the maximum of::

            (base priority + rescore boost + wait // aging_interval,
             affinity hits on the worker's manifest,
             queue completion rate,
             -seq)                                # FIFO tie-break

        Losing candidates are pushed straight back (their heap entries
        are still valid).  The unbounded aging term makes this
        starvation-proof: affinity and completion rate only reorder
        within one effective-priority level."""
        intel = self._intel
        allowed = list(queues) if queues else list(self._heaps)
        popped: List[Tuple[str, Tuple[int, int, str], _Job]] = []
        for q in allowed:
            heap = self._heaps.get(q)
            if not heap:
                continue
            cap = self.queue_caps.get(q)
            if cap is not None and self._queue_active.get(q, 0) >= cap:
                continue  # throttled: queue at its outstanding-lease cap
            taken = 0
            while heap and taken < intel.scan_width:
                entry = heapq.heappop(heap)
                neg_pr, seq, jid = entry
                job = self._jobs.get(jid)
                if (job is None or job.state != _PENDING
                        or job.seq != seq or job.queue != q):
                    continue  # lazy deletion, exactly as _pop_best
                popped.append((q, entry, job))
                taken += 1
        if not popped:
            return None
        best_i = 0
        best_score: Optional[Tuple[float, int, float, int]] = None
        best_hits = best_boost = 0
        for i, (q, _entry, job) in enumerate(popped):
            boost = int(max(0.0, now - job.enqueued)
                        // intel.aging_interval)
            eff_pr = (job.priority + boost
                      + self._queue_boost.get(q, 0))
            hits = (intel.affinity.score(worker_id,
                                         job.proc.input_files, now)
                    if job.proc.input_files else 0)
            score = (eff_pr, hits, intel.history.completion_rate(q),
                     -job.seq)
            if best_score is None or score > best_score:
                best_i, best_score = i, score
                best_hits, best_boost = hits, boost
        winner_q, _entry, winner = popped.pop(best_i)
        for q, entry, _job in popped:
            heapq.heappush(self._heaps[q], entry)
        obs = self._obs_intel
        if winner.proc.input_files:
            # hit-rate denominator: only jobs that HAVE inputs to hit
            if best_hits > 0:
                intel.affinity_hits += 1
                if obs is not None:
                    obs["affinity_hit"].inc()
            else:
                intel.affinity_misses += 1
                if obs is not None:
                    obs["affinity_miss"].inc()
        if best_boost > 0:
            intel.aging_promotions += 1
            if obs is not None:
                obs["aging_promotion"].inc()
        return winner

    def _job_payload(self, job: _Job) -> Dict[str, Any]:
        p = job.proc
        return {
            "job_id": p.proc_id,
            "payload": p.payload,
            "params": dict(p.params),
            "input_files": list(p.input_files),
            "attempt": job.attempt,
            "max_attempts": p.max_attempts,
            "queue": job.queue,
            "priority": job.priority,
            "lease": {
                "lease_id": job.lease.lease_id,
                "worker_id": job.lease.worker_id,
                "ttl": job.lease.ttl,
            },
        }

    # ---------------------------------------------------------- heartbeat
    def heartbeat(self, job_id: str, worker_id: str,
                  manifest: Optional[List[str]] = None) -> Dict[str, Any]:
        """Renew the lease on ``job_id``; raises SchedulerConflict if the
        worker no longer holds it (expired → requeued, or reassigned)."""
        out = self.heartbeat_many(worker_id, [job_id],
                                  manifest=manifest)[0]
        if not out["ok"]:
            raise SchedulerConflict(out["error"])
        return {"ok": True, "lease_id": out["lease_id"],
                "deadline_in": out["deadline_in"]}

    def heartbeat_many(self, worker_id: str, job_ids: List[str],
                       manifest: Optional[List[str]] = None
                       ) -> List[Dict[str, Any]]:
        """Renew many leases in ONE lock acquisition and ONE journal
        commit.  Per-item results — ``{"job_id", "ok": True, "lease_id",
        "deadline_in"}`` or ``{"job_id", "ok": False, "error"}`` — so one
        stale lease cannot poison the rest of the batch.  ``manifest``
        is the worker's volunteered held-content report; it feeds the
        affinity index when the intelligence plane is attached and is
        ignored (accepted, unused) otherwise."""
        obs = self._obs_heartbeat
        t0 = time.monotonic() if obs is not None else 0.0
        now = self._clock()
        results: List[Dict[str, Any]] = []
        with self._lock:
            self._expire_locked(now)
            self._touch_worker(worker_id)
            if manifest is not None and self._intel is not None:
                self._intel.affinity.update(worker_id, manifest, now)
            renewed: List[_Job] = []
            for job_id in job_ids:
                try:
                    job = self._require_holder(job_id, worker_id,
                                               "heartbeat")
                except SchedulerConflict as e:
                    results.append({"job_id": job_id, "ok": False,
                                    "error": str(e)})
                    continue
                job.lease.deadline = now + job.lease.ttl
                heapq.heappush(self._deadlines,
                               (job.lease.deadline, job.lease.lease_id,
                                job_id))
                renewed.append(job)
                results.append({"job_id": job_id, "ok": True,
                                "lease_id": job.lease.lease_id,
                                "deadline_in": job.lease.ttl})
            self._journal_leases(renewed)
        if obs is not None:
            obs.observe(time.monotonic() - t0)
        return results

    # ----------------------------------------------------------- complete
    def complete(self, job_id: str, worker_id: str, *,
                 result: Optional[Dict[str, Any]] = None,
                 error: Optional[str] = None) -> Dict[str, Any]:
        """Record a worker's outcome.  Idempotent for the worker that
        holds (or already completed) the job; any other reporter — e.g.
        a stale worker whose lease expired and whose job was requeued —
        gets a SchedulerConflict and causes no state change."""
        out = self.complete_many(worker_id, [(job_id, result, error)])[0]
        if not out["ok"]:
            raise SchedulerConflict(out["error"])
        return {"ok": True, "duplicate": out["duplicate"]}

    def complete_many(
            self, worker_id: str,
            items: List[Tuple[str, Optional[Dict[str, Any]],
                              Optional[str]]]) -> List[Dict[str, Any]]:
        """Record many outcomes — ``(job_id, result, error)`` triples —
        in ONE lock acquisition.  Per-item results mirror ``complete``:
        ``{"job_id", "ok": True, "duplicate"}`` on success, ``{"job_id",
        "ok": False, "error"}`` for per-item conflicts."""
        obs = self._obs_complete
        t0 = time.monotonic() if obs is not None else 0.0
        now = self._clock()
        results: List[Dict[str, Any]] = []
        completed: List[Tuple[str, Optional[str]]] = []
        durations: List[float] = []  # flushed in one observe_many below
        with self._lock:
            self._expire_locked(now)
            self._touch_worker(worker_id)
            for job_id, result, error in items:
                job = self._jobs.get(job_id)
                if (job is not None and job.state == _DONE
                        and job.completed_by == worker_id):
                    results.append({"job_id": job_id, "ok": True,
                                    "duplicate": True})  # idempotent retry
                    continue
                try:
                    job = self._require_holder(job_id, worker_id,
                                               "completion")
                except SchedulerConflict as e:
                    results.append({"job_id": job_id, "ok": False,
                                    "error": str(e)})
                    continue
                status = "failed" if error else "finished"
                job.outcome = (status, result, error, job.attempt)
                job.completed_by = worker_id
                if (self._obs_job_dur is not None
                        and job.lease.granted > 0.0):
                    durations.append(now - job.lease.granted)
                if self._intel is not None:
                    self._intel.history.record_job(
                        job.queue,
                        (now - job.lease.granted
                         if job.lease.granted > 0.0 else None),
                        ok=not error)
                self._release_lease(job)  # drops the holder's lease count
                job.state = _DONE
                self._retire(job)
                w = self._workers[worker_id]
                w["jobs_failed" if error else "jobs_completed"] += 1
                self._bump("jobs_failed_by_worker" if error
                           else "jobs_completed_by_worker")
                completed.append((job_id, error))
                results.append({"job_id": job_id, "ok": True,
                                "duplicate": False})
        if durations:
            self._obs_job_dur.observe_many(durations)
        if obs is not None:
            obs.observe(time.monotonic() - t0)
        if self._on_event is not None:
            for job_id, error in completed:
                self._on_event("job_completed", job_id,
                               {"worker_id": worker_id,
                                "failed": bool(error)})
        return results

    def _require_holder(self, job_id: str, worker_id: str,
                        verb: str) -> _Job:
        job = self._jobs.get(job_id)
        if job is None:
            raise SchedulerConflict(f"{verb} rejected: unknown job "
                                    f"{job_id!r}")
        if job.state != _LEASED or job.lease is None:
            raise SchedulerConflict(
                f"{verb} rejected: job {job_id!r} is not leased "
                f"(state={job.state})")
        if job.lease.worker_id != worker_id:
            raise SchedulerConflict(
                f"{verb} rejected: job {job_id!r} is leased by "
                f"{job.lease.worker_id!r}, not {worker_id!r}")
        return job

    def _release_lease(self, job: _Job) -> None:
        if job.lease is None:
            return
        w = self._workers.get(job.lease.worker_id)
        if w is not None:
            w["active_leases"] = max(0, w["active_leases"] - 1)
        self._queue_active[job.queue] = max(
            0, self._queue_active.get(job.queue, 0) - 1)
        job.lease = None
        # the idempotency key only replays while the lease is held, so
        # release ends the key's life for this job (the key itself dies
        # with its last outstanding job, bounding the key map)
        if job.lease_key is not None:
            jids = self._lease_keys.get(job.lease_key)
            if jids is not None:
                try:
                    jids.remove(job.proc.proc_id)
                except ValueError:
                    pass
                if not jids:
                    self._lease_keys.pop(job.lease_key, None)
            job.lease_key = None
        self._drop_lease_row(job.proc.proc_id)

    def _retire(self, job: _Job) -> None:
        """Bound memory: DONE jobs are retained (for duplicate-completion
        dedup and stale-worker 409s) up to ``retain_done``, oldest out."""
        self._done_ring.append(job.proc.proc_id)
        while len(self._done_ring) > self._retain_done:
            old = self._done_ring.popleft()
            j = self._jobs.get(old)
            if j is not None and j.state == _DONE and j.outcome is None:
                del self._jobs[old]

    # ----------------------------------------- steering (lifecycle plane)
    def fence_jobs(self, proc_ids: List[str]) -> int:
        """Suspend: make these jobs unleasable.  A held lease is revoked
        — the worker observes the fence as a 409 on its next heartbeat
        (or completion) and drops the job — *without* consuming an
        attempt (suspension is not a failure).  Returns #jobs fenced."""
        with self._lock:
            n = 0
            for pid in proc_ids:
                job = self._jobs.get(pid)
                if job is None or job.state in (_DONE, _SUSPENDED):
                    continue
                if job.state == _LEASED:
                    self._release_lease(job)
                    self._bump("leases_fenced")
                job.state = _SUSPENDED
                n += 1
            return n

    def resume_jobs(self, proc_ids: List[str]) -> int:
        """Resume: re-queue jobs fenced by ``fence_jobs``."""
        with self._lock:
            n = 0
            for pid in proc_ids:
                job = self._jobs.get(pid)
                if job is None or job.state != _SUSPENDED:
                    continue
                self._seq += 1
                job.seq = self._seq
                self._push(job)
                n += 1
            return n

    def revoke_jobs(self, proc_ids: List[str]) -> int:
        """Abort: retire these jobs with no outcome.  A held lease is
        revoked (stale worker reports get a 409); the job is never
        requeued and ``take_outcome`` never surfaces it — the Carrier
        drops the cancelled Processing on its own."""
        with self._lock:
            n = 0
            for pid in proc_ids:
                job = self._jobs.get(pid)
                if job is None or job.state == _DONE:
                    continue
                if job.state == _LEASED:
                    self._release_lease(job)
                    self._bump("leases_revoked")
                job.state = _DONE
                job.outcome = None
                self._retire(job)
                n += 1
            return n

    # -------------------------------------------------------------- expiry
    def expire(self) -> int:
        """Requeue every job whose lease deadline passed; returns how
        many.  Runs implicitly on every lease/heartbeat/complete/poll,
        so a dedicated reaper thread is unnecessary."""
        with self._lock:
            return self._expire_locked(self._clock())

    def _expire_locked(self, now: float) -> int:
        # amortized registry pruning: worker ids embed pid + random
        # suffixes, so a churning fleet would otherwise grow _workers
        # monotonically.  Entries silent for 10× worker_ttl with nothing
        # leased are gone for good — drop them (at most once per ttl).
        if now >= self._next_worker_prune:
            self._next_worker_prune = now + self.worker_ttl
            cutoff = now - 10.0 * self.worker_ttl
            for wid in [wid for wid, w in self._workers.items()
                        if w["last_seen"] < cutoff
                        and w["active_leases"] == 0]:
                del self._workers[wid]
        n = 0
        while self._deadlines and self._deadlines[0][0] <= now:
            deadline, lease_id, job_id = heapq.heappop(self._deadlines)
            job = self._jobs.get(job_id)
            if (job is None or job.state != _LEASED or job.lease is None
                    or job.lease.lease_id != lease_id
                    or job.lease.deadline != deadline):
                continue  # stale entry: renewed, completed, or revoked
            worker = job.lease.worker_id
            self._release_lease(job)
            self._bump("lease_expiries")
            n += 1
            if job.attempt < job.proc.max_attempts:
                # consume an attempt exactly as the Carrier's retry path
                # would, then hand the job to the next worker
                job.attempt += 1
                job.proc.attempt = job.attempt
                self._seq += 1
                job.seq = self._seq
                self._push(job)
                self._bump("lease_requeues")
            else:
                job.outcome = (
                    "failed", None,
                    f"lease expired (worker {worker!r}); "
                    f"{job.attempt} attempts exhausted", job.attempt)
                job.state = _DONE
                self._retire(job)
                if self._intel is not None:
                    self._intel.history.record_job(job.queue, None,
                                                   ok=False)
        return n

    # ------------------------------------------------------------- outcome
    def take_outcome(self, proc_id: str) -> Optional[
            Tuple[str, Any, Optional[str], int]]:
        """Pop the terminal outcome for ``proc_id`` if one is ready:
        ``(status, result, error, attempt)``.  Called by
        ``DistributedWFM.poll`` from the Carrier thread."""
        with self._lock:
            self._expire_locked(self._clock())
            job = self._jobs.get(proc_id)
            if job is None or job.state != _DONE or job.outcome is None:
                return None
            out, job.outcome = job.outcome, None
            return out

    # ------------------------------------------------------------- workers
    def _touch_worker(self, worker_id: str) -> None:
        w = self._workers.get(worker_id)
        if w is None:
            w = self._workers[worker_id] = {
                "worker_id": worker_id, "active_leases": 0,
                "jobs_completed": 0, "jobs_failed": 0, "last_seen": 0.0}
        w["last_seen"] = self._clock()

    def workers(self) -> List[Dict[str, Any]]:
        """Per-worker registry snapshot (GET /workers)."""
        now = self._clock()
        with self._lock:
            self._expire_locked(now)
            return [{
                "worker_id": w["worker_id"],
                "active_leases": w["active_leases"],
                "jobs_completed": w["jobs_completed"],
                "jobs_failed": w["jobs_failed"],
                "last_seen_ago_s": round(now - w["last_seen"], 3),
                "connected": (now - w["last_seen"]) < self.worker_ttl,
            } for w in self._workers.values()]

    def worker_count(self) -> int:
        """Workers seen within ``worker_ttl`` (healthz)."""
        now = self._clock()
        with self._lock:
            return sum(1 for w in self._workers.values()
                       if (now - w["last_seen"]) < self.worker_ttl)

    def queue_depths(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            out: Dict[str, Dict[str, int]] = {}
            for jid, job in self._jobs.items():
                if job.state in (_PENDING, _LEASED, _SUSPENDED):
                    q = out.setdefault(job.queue, {"pending": 0,
                                                   "leased": 0,
                                                   "suspended": 0})
                    q[job.state] += 1
            return out

    def queue_stats(self) -> Dict[str, Dict[str, Any]]:
        """Operator surface for ``GET /v1/queues``: depths plus the
        intelligence plane's view of each queue — rescore boost,
        effective priority (the best pending job's aged score) and
        learned completion rate.  With intel off the depths are the
        same and the learned fields stay at their neutral defaults."""
        now = self._clock()
        intel = self._intel
        with self._lock:
            out: Dict[str, Dict[str, Any]] = {}
            for job in self._jobs.values():
                if job.state not in (_PENDING, _LEASED, _SUSPENDED):
                    continue
                q = out.setdefault(job.queue, {
                    "pending": 0, "leased": 0, "suspended": 0,
                    "base_priority": job.priority,
                    "effective_priority": job.priority})
                q[job.state] += 1
                q["base_priority"] = max(q["base_priority"], job.priority)
                eff = job.priority
                if intel is not None and job.state == _PENDING:
                    eff += (int(max(0.0, now - job.enqueued)
                                // intel.aging_interval)
                            + self._queue_boost.get(job.queue, 0))
                q["effective_priority"] = max(q["effective_priority"],
                                              eff)
            for name, q in out.items():
                q["cap"] = self.queue_caps.get(name)
                q["boost"] = self._queue_boost.get(name, 0)
                q["completion_rate"] = (
                    round(intel.history.completion_rate(name), 4)
                    if intel is not None else None)
            return out

    def prune_affinity(self) -> int:
        """Expire worker manifests not refreshed within the affinity
        TTL (Watchdog housekeeping); returns how many were dropped."""
        if self._intel is None:
            return 0
        return self._intel.affinity.prune(self._clock())

    def rescore_queue_priorities(self) -> Dict[str, int]:
        """Watchdog hook (adaptive reprioritization): refresh per-queue
        priority boosts from the HistoryBook's observed completion
        rates — ±1 level, see ``IntelPlane.rescore_boost``.  Returns
        the boosts that changed; a no-op with intel off."""
        intel = self._intel
        if intel is None:
            return {}
        changed: Dict[str, int] = {}
        with self._lock:
            for q in set(self._heaps) | set(self._queue_boost):
                boost = intel.rescore_boost(q)
                if self._queue_boost.get(q, 0) != boost:
                    if boost:
                        self._queue_boost[q] = boost
                    else:
                        self._queue_boost.pop(q, None)
                    changed[q] = boost
        if changed:
            intel.rescores += len(changed)
            obs = self._obs_intel
            if obs is not None:
                obs["queue_rescore"].inc(len(changed))
            self._bump("intel_queue_rescores", len(changed))
        return changed

    def shutdown(self) -> None:
        """Stop handing out new leases (in-flight ones may still report)."""
        with self._lock:
            self._draining = True


# ---------------------------------------------------------------------------
# The executor the Carrier drives
# ---------------------------------------------------------------------------


class DistributedWFM(WFMExecutor):
    """WFM boundary backed by remote pull-based workers.

    ``submit`` enqueues the Processing on the :class:`JobScheduler`;
    ``poll`` applies worker-reported outcomes (and drives lease expiry).
    The Carrier's retry semantics are unchanged: a worker-reported
    failure surfaces as a FAILED poll and the Carrier re-submits with
    ``attempt + 1``; a lease expiry consumes attempts inside the
    scheduler and only surfaces FAILED once they are exhausted.
    """

    def __init__(self, *, scheduler: Optional[JobScheduler] = None,
                 lease_ttl: float = 30.0,
                 queue_caps: Optional[Dict[str, int]] = None,
                 intel: bool = False):
        # no super().__init__: there is no in-process thread pool
        self.sync = False
        self.fault_hook = None
        self.scheduler = scheduler if scheduler is not None else \
            JobScheduler(default_ttl=lease_ttl, queue_caps=queue_caps)
        if intel and self.scheduler.intel is None:
            self.scheduler.enable_intel()
        self.submitted = 0
        self._lock = threading.RLock()

    def attach(self, ctx) -> None:
        self.scheduler.attach(ctx.store, on_stat=ctx.bump,
                              metrics=getattr(ctx, "metrics", None),
                              on_event=getattr(ctx, "sched_event", None))
        intel = self.scheduler.intel
        if intel is not None:
            # warm start: replay the journaled per-queue history so a
            # restarted head dispatches on learned rates immediately
            try:
                intel.history.load(ctx.store.load_stats(scope="queue"))
            except NotImplementedError:  # a stats-less custom store
                pass

    def submit(self, proc: Processing) -> None:
        with self._lock:
            self.submitted += 1
        proc.status = ProcessingStatus.SUBMITTED
        self.scheduler.enqueue(proc)

    def fence(self, procs: List[Processing]) -> None:
        self.scheduler.fence_jobs([p.proc_id for p in procs])

    def release(self, procs: List[Processing]) -> None:
        self.scheduler.resume_jobs([p.proc_id for p in procs])

    def cancel(self, procs: List[Processing]) -> None:
        self.scheduler.revoke_jobs([p.proc_id for p in procs])

    def poll(self, proc: Processing) -> Processing:
        out = self.scheduler.take_outcome(proc.proc_id)
        if out is None:
            return proc
        status, result, error, attempt = out
        proc.attempt = attempt
        proc.error = error
        if status == "finished":
            proc.result = result
            proc.status = ProcessingStatus.FINISHED
        else:
            proc.status = ProcessingStatus.FAILED
        return proc

    def shutdown(self) -> None:
        self.scheduler.shutdown()
