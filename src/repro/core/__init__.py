"""iDDS core: the paper's primary contribution.

Workflow DG engine, the six daemons (including the steering-plane
Commander), the message bus, the JSON request boundary, the declarative
WorkflowSpec builder, and the services built on top (HPO, Active
Learning, Rubin-style job DAGs).
"""
from repro.core.commands import (  # noqa: F401
    Command,
    CommandConflict,
)
from repro.core.idds import IDDS, AuthError  # noqa: F401
from repro.core.requests import Request  # noqa: F401
from repro.core.spec import WorkflowSpec, WorkStep  # noqa: F401
from repro.core.store import (  # noqa: F401
    InMemoryStore,
    SqliteStore,
    Store,
    StoreError,
)
from repro.core.workflow import (  # noqa: F401
    Branch,
    Collection,
    Condition,
    FileRef,
    Processing,
    ProcessingStatus,
    Work,
    WorkStatus,
    Workflow,
    WorkTemplate,
)
