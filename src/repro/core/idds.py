"""iDDS head service: the RESTful facade + daemon runner.

Authenticates users, registers and queries requests, and provides an
interface to look up data collections/contents (paper §2).  Two execution
modes:

  * ``pump()``      — deterministic: cycle the daemons until the system is
                      quiescent (unit tests, simulators);
  * ``start()/stop()`` — production: one thread per daemon + threaded WFM
                      pool, requests served concurrently.

The HTTP layer is intentionally thin (a real deployment puts Flask/nginx
in front); every entry point already speaks JSON strings, so the daemons
never see Python objects from the client.
"""
from __future__ import annotations

import threading
import time
import uuid
from typing import Any, Callable, Dict, List, Optional, Set, Union

from repro.core import messaging as M
from repro.core.commands import (CTRL_ABORTED, CTRL_SUSPENDED,
                                 VALID_COMMAND_ACTIONS, Command,
                                 CommandConflict)
from repro.core.daemons import (ALL_DAEMONS, Context, Transformer, Watchdog,
                                WFMExecutor)
from repro.core.ddm import DDM, InMemoryDDM
from repro.core.delivery import (DELIVERY_STATUSES, UNDELIVERED_STATUSES,
                                 Subscription, content_key)
from repro.core.obs import (MetricsRegistry, Tracer, build_trace,
                            new_trace_id, render_snapshots)
from repro.core.requests import Request
from repro.core.store import (InMemoryStore, Store,
                              VALID_REQUEST_STATUSES, _content_rank)
from repro.core.workflow import (CONTENT_STATUSES, FileRef, Processing,
                                 ProcessingStatus, Work, Workflow)


class AuthError(Exception):
    pass


class IDDS:
    def __init__(self, *, ddm: Optional[DDM] = None, sync: bool = True,
                 max_workers: int = 8,
                 fault_hook: Optional[Callable] = None,
                 tokens: Optional[Set[str]] = None,
                 store: Optional[Store] = None,
                 executor: Optional[WFMExecutor] = None,
                 bus: Union[str, M.BusBackend] = "local",
                 head_id: Optional[str] = None,
                 claim_ttl: float = 5.0,
                 telemetry: bool = True):
        store = store if store is not None else InMemoryStore()
        head_id = head_id or f"head-{uuid.uuid4().hex[:8]}"
        # bus= selects the backend: "local" (in-process, single head),
        # "store" (journal events through the store so peer heads' daemons
        # wake on this head's announcements), or a pre-built BusBackend
        # (tests sharing one bus across two in-process heads)
        if isinstance(bus, str):
            bus = M.make_bus(bus, store=store, head_id=head_id)
        # executor= overrides the inline WFM: pass a DistributedWFM
        # (repro.core.scheduler) to dispatch Processings to pull-based
        # remote workers instead of executing them in-process
        wfm = (executor if executor is not None else
               WFMExecutor(sync=sync, max_workers=max_workers,
                           fault_hook=fault_hook))
        self.ctx = Context(
            bus=bus,
            ddm=ddm if ddm is not None else InMemoryDDM(),
            wfm=wfm,
            store=store,
            head_id=head_id,
            claim_ttl=claim_ttl,
        )
        # telemetry plane: one registry + tracer per head, threaded
        # through the Context so daemons/store/bus/scheduler all report
        # into the same exposition (set BEFORE wfm.attach — the
        # distributed executor binds scheduler metrics from ctx there).
        # telemetry=False hands out no-op instruments and an inert
        # tracer — the obs_bench overhead arm's baseline
        self.metrics = MetricsRegistry(head_id=head_id, enabled=telemetry)
        self.tracer = Tracer(
            store, head_id, enabled=telemetry,
            on_fault=lambda _e: self.ctx.bump("trace_faults"))
        self.ctx.metrics = self.metrics
        self.ctx.tracer = self.tracer
        self.ctx.sched_event = self._sched_event
        store.bind_metrics(self.metrics)
        bind_bus = getattr(bus, "bind_metrics", None)
        if callable(bind_bus):
            bind_bus(self.metrics)
        self._ack_hist = self.metrics.histogram(
            "conductor_ack_seconds",
            "delivery notify-to-ack latency").labels()
        self._pub_ack_hist = self.metrics.histogram(
            "outbox_publish_ack_seconds",
            "outbox publish-to-ack latency").labels()
        # push-delivery wake plane: long-poll and SSE handlers park on
        # this condition; the bus subscription wakes them on every
        # addressed consumer notification the Publisher fans out
        self._delivery_cv = threading.Condition()
        self._publish_ts: Dict[str, float] = {}
        bus.subscribe(M.T_CONSUMER_NOTIFY, self._on_notify)
        wfm.attach(self.ctx)
        # a bindable DDM (CarouselDDM) gets the head's bus + store, so
        # its per-file staging transitions are announced to the
        # Transformer AND journaled for crash recovery
        bind = getattr(self.ctx.ddm, "bind", None)
        if callable(bind):
            bind(bus=self.ctx.bus, store=self.ctx.store)
        bind_tel = getattr(self.ctx.ddm, "bind_telemetry", None)
        if callable(bind_tel):
            bind_tel(self.metrics, self.tracer)
        self.daemons = [cls(self.ctx) for cls in ALL_DAEMONS]
        # the Watchdog adopts workflows whose head died through this
        # head's claim-aware scoped recovery
        self.watchdog = next(d for d in self.daemons
                             if isinstance(d, Watchdog))
        self.watchdog.adopt = self._adopt_workflow
        self._tokens = tokens  # None -> auth disabled (dev mode)
        # shared with Context so the Marshaller can write request status
        # transitions through to the catalog as they happen
        self._requests = self.ctx.requests
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self._recovered_collections: Set[str] = set()

    @property
    def store(self) -> Store:
        return self.ctx.store

    @property
    def scheduler(self):
        """The lease scheduler when running a DistributedWFM executor,
        else None (inline execution — no jobs to lease)."""
        return getattr(self.ctx.wfm, "scheduler", None)

    def daemon_liveness(self) -> Dict[str, bool]:
        """Per-daemon liveness for operators (/healthz).  In threaded
        mode this reflects the actual thread state; in pump mode the
        daemons run inside the caller's pump and are reported alive."""
        if not self._threads:
            return {d.name: True for d in self.daemons}
        alive = {t.name: t.is_alive() for t in self._threads}
        return {d.name: alive.get(f"idds-{d.name}", False)
                for d in self.daemons}

    # ------------------------------------------------------------------ auth
    def _auth(self, token: str) -> None:
        if self._tokens is not None and token not in self._tokens:
            raise AuthError("invalid token")

    # ------------------------------------------------------------ telemetry
    def _sched_event(self, event: str, proc_id: str,
                     data: Dict[str, Any]) -> None:
        """Scheduler → tracer adapter: the scheduler only knows job
        ids (proc ids for WFM-dispatched jobs); resolve the owning
        request so lease/completion events land on its timeline."""
        rid = tid = None
        with self.ctx.lock:
            p = self.ctx.processings.get(proc_id)
            if p is not None and p.work_id in self.ctx.works:
                wf_id = self.ctx.works[p.work_id][0]
                rid = self.ctx.request_of.get(wf_id)
                tid = self.ctx.trace_ids.get(wf_id)
        self.ctx.trace(event, request_id=rid, trace_id=tid,
                       entity=proc_id, data=data)

    # -------------------------------------------------------------- client API
    def submit(self, request_json: str) -> str:
        """Accept a serialized Request; returns the request_id.

        Idempotent on request_id: resubmitting an already-registered
        request (an HTTP client retrying after a lost response) is a
        no-op, so the workflow never runs twice.
        """
        req = Request.from_json(request_json)
        self._auth(req.token)
        trace_id = new_trace_id()
        info = {
            "request_id": req.request_id,
            "workflow_id": req.workflow.workflow_id,
            "requester": req.requester,
            "status": "accepted",
            "submitted_at": time.time(),
            "trace_id": trace_id,
        }
        with self.ctx.lock:
            if req.request_id in self._requests:
                return req.request_id
            self._requests[req.request_id] = info
            self.ctx.request_of[req.workflow.workflow_id] = req.request_id
            self.ctx.trace_ids[req.workflow.workflow_id] = trace_id
        # journal workflow structure before the request row: recovery can
        # always re-run a journaled workflow, while a request without its
        # workflow would be stuck at "accepted" forever
        wf_meta = req.workflow.to_dict()
        works = wf_meta.pop("works", {})
        self.ctx.store.save_workflow(wf_meta)
        if works:  # client-side pre-instantiated works ride along
            self.ctx.store.save_works(req.workflow.workflow_id,
                                      list(works.values()))
        self.ctx.store.save_request(info)
        self.ctx.trace("submitted", request_id=req.request_id,
                       trace_id=trace_id,
                       data={"requester": req.requester,
                             "workflow_id": req.workflow.workflow_id})
        self.ctx.bus.publish(M.T_NEW_REQUESTS, {
            "request_id": req.request_id,
            "workflow": req.workflow.to_json(),
        }, trace_id=trace_id)
        return req.request_id

    def submit_workflow(self, wf: Workflow, requester: str = "anonymous",
                        token: str = "") -> str:
        return self.submit(Request(workflow=wf, requester=requester,
                                   token=token).to_json())

    def request_status(self, request_id: str) -> Dict[str, Any]:
        shared = self._requests.get(request_id)
        if shared is None:
            # not in this head's mirror: the request was submitted
            # through another head.  Serve the journaled catalog row
            # (KeyError -> 404 when the store has no row either).
            row = self.ctx.store.get_request(request_id)
            if row is None:
                raise KeyError(request_id)
            with self.ctx.lock:
                shared = self._requests.setdefault(request_id, dict(row))
        info = dict(shared)
        wf = self.ctx.workflows.get(info["workflow_id"])
        if wf is None:
            # another head owns this workflow: refresh from the catalog
            # per poll — the owner writes status transitions through as
            # they happen, and this head must not serve its stale seed
            row = self.ctx.store.get_request(request_id)
            if row is not None:
                with self.ctx.lock:
                    shared.update(row)
                info = dict(shared)
        with self.ctx.lock:
            ctrl = self.ctx.control.get(info["workflow_id"])
            cmds = list(self.ctx.commands_by_request.get(request_id, ()))
        # pollers distinguish "suspended" from "stuck": the flag plus the
        # command tally ride on every status response (the catalog row's
        # flag stands in when another head owns the workflow)
        info["suspended"] = (ctrl == CTRL_SUSPENDED if wf is not None
                             else bool(info.get("suspended")))
        info["commands"] = {"total": len(cmds),
                            "pending": sum(1 for c in cmds if c.pending)}
        if wf is not None:
            # snapshot under ctx.lock: daemon threads insert into wf.works
            # (iteration would race), and finished+quiescent must be read
            # against the same instant or a poll between the Marshaller's
            # successor-instantiation and its inflight decrement could
            # still report a false "finished"
            with self.ctx.lock:
                info["works"] = wf.counts()
                done = wf.finished and self.ctx.quiescent(wf.workflow_id)
            if ctrl is not None:
                info["status"] = ctrl  # "suspended" | "aborted"
            else:
                info["status"] = "finished" if done else "running"
            if shared.get("status") != info["status"]:
                # write the observed transition through to the catalog so
                # GET /requests?status= filters stay truthful
                with self.ctx.lock:
                    shared["status"] = info["status"]
                self.ctx.store.save_request(
                    {k: v for k, v in info.items()
                     if k not in ("works", "commands")})
        return info

    def list_requests(self, *, status: Optional[str] = None,
                      limit: Optional[int] = None,
                      offset: int = 0) -> Dict[str, Any]:
        """Catalog listing with status filtering and limit/offset
        pagination, backed by store queries (GET /requests)."""
        if status is not None and status not in VALID_REQUEST_STATUSES:
            raise ValueError(
                f"invalid status filter {status!r}; expected one of "
                f"{', '.join(VALID_REQUEST_STATUSES)}")
        if limit is not None and (isinstance(limit, bool)
                                  or not isinstance(limit, int)
                                  or limit < 0):
            raise ValueError("limit must be a non-negative integer")
        if isinstance(offset, bool) or not isinstance(offset, int) \
                or offset < 0:
            raise ValueError("offset must be a non-negative integer")
        # no per-call refresh: the Marshaller writes request transitions
        # through to the catalog at the events that cause them, and
        # request_status() writes through on observation — listings read
        # fresh rows at O(page), not O(all requests)
        return {
            "requests": self.ctx.store.list_requests(
                status=status, limit=limit, offset=offset),
            "total": self.ctx.store.count_requests(status=status),
            "limit": limit,
            "offset": offset,
        }

    def get_workflow(self, request_id: str) -> Workflow:
        return self.ctx.workflows[self._requests[request_id]["workflow_id"]]

    def workflow_dict(self, request_id: str) -> Dict[str, Any]:
        """Serialized workflow snapshot, safe against live daemon threads."""
        wf = self.get_workflow(request_id)
        with self.ctx.lock:
            return wf.to_dict()

    def list_transforms(self, request_id: str) -> Dict[str, Any]:
        """The request's Works as first-class read resources (the paper's
        transforms), with per-work status for steering operators."""
        wf = self.get_workflow(request_id)
        with self.ctx.lock:
            transforms = [w.to_dict() for w in wf.works.values()]
        return {"transforms": transforms, "total": len(transforms)}

    def list_processings(self, request_id: str) -> Dict[str, Any]:
        """The request's Processings as first-class read resources."""
        wf = self.get_workflow(request_id)
        with self.ctx.lock:
            procs = [p.to_dict() for p in self.ctx.processings.values()
                     if p.work_id in wf.works]
        return {"processings": procs, "total": len(procs)}

    # ------------------------------------------------------------- steering
    def command(self, request_id: str, action: str, *,
                command_id: Optional[str] = None) -> Dict[str, Any]:
        """Submit a lifecycle command against a request.

        Journals the command (``pending``) before announcing it, so a
        crash between the two is replayed by ``recover()``.  Idempotent
        on ``command_id``: resubmitting a known command (an HTTP client
        retrying after a lost response) returns its current state
        instead of applying the action twice.

        Raises ``KeyError`` (unknown request), ``ValueError`` (unknown
        action) or :class:`~repro.core.commands.CommandConflict` (the
        action cannot apply to the request's current lifecycle state).
        """
        if action not in VALID_COMMAND_ACTIONS:
            raise ValueError(
                f"invalid action {action!r}; expected one of "
                f"{', '.join(VALID_COMMAND_ACTIONS)}")
        if request_id not in self._requests:
            # submitted through another head: learn the catalog row
            # (KeyError -> 404 when the store has no row either)
            row = self.ctx.store.get_request(request_id)
            if row is None:
                raise KeyError(request_id)
            with self.ctx.lock:
                self._requests.setdefault(request_id, dict(row))
        with self.ctx.lock:
            info = self._requests[request_id]  # KeyError -> 404
            if command_id and command_id in self.ctx.commands:
                existing = self.ctx.commands[command_id]
                if (existing.request_id != request_id
                        or existing.action != action):
                    # a replay must BE a replay — echoing back some
                    # other request's command would silently drop the
                    # caller's intended action
                    raise CommandConflict(
                        f"command_id {command_id!r} was already used "
                        f"for {existing.action!r} on request "
                        f"{existing.request_id!r}")
                return existing.to_dict()
            wf_id = info["workflow_id"]
            ctrl = self.ctx.control.get(wf_id)
            # strict submit-time checks (the Commander itself is lenient
            # so crash-replays of already-applied commands degrade to
            # no-ops instead of spurious failures)
            if ctrl == CTRL_ABORTED and action != "abort":
                raise CommandConflict(
                    f"request {request_id!r} is aborted; only a "
                    f"duplicate abort is accepted")
            if action == "resume" and ctrl != CTRL_SUSPENDED:
                raise CommandConflict(
                    f"request {request_id!r} is not suspended")
            if action == "suspend" and ctrl is None:
                wf = self.ctx.workflows.get(wf_id)
                if (wf is not None and wf.finished
                        and self.ctx.quiescent(wf_id)):
                    raise CommandConflict(
                        f"request {request_id!r} already finished; "
                        f"nothing to suspend")
            cmd = Command(request_id=request_id, action=action,
                          workflow_id=wf_id,
                          **({"command_id": command_id}
                             if command_id else {}))
            self.ctx.register_command(cmd)
            d = cmd.to_dict()
        # journal BEFORE announcing: a command on the bus but not in the
        # store would be lost by a crash; the reverse is replayed
        self.ctx.store.save_command(d)
        self.ctx.bus.publish(M.T_NEW_COMMANDS,
                             {"command_id": cmd.command_id,
                              "request_id": request_id,
                              "workflow_id": wf_id})
        return d

    def abort(self, request_id: str, **kw) -> Dict[str, Any]:
        return self.command(request_id, "abort", **kw)

    def suspend(self, request_id: str, **kw) -> Dict[str, Any]:
        return self.command(request_id, "suspend", **kw)

    def resume(self, request_id: str, **kw) -> Dict[str, Any]:
        return self.command(request_id, "resume", **kw)

    def retry(self, request_id: str, **kw) -> Dict[str, Any]:
        return self.command(request_id, "retry", **kw)

    def get_command(self, request_id: str,
                    command_id: str) -> Dict[str, Any]:
        with self.ctx.lock:
            cmd = self.ctx.commands.get(command_id)
            if cmd is None or cmd.request_id != request_id:
                raise KeyError(f"unknown command {command_id!r} for "
                               f"request {request_id!r}")
            return cmd.to_dict()

    def list_commands(self, request_id: str) -> Dict[str, Any]:
        self._requests[request_id]  # KeyError -> 404
        with self.ctx.lock:
            cmds = [c.to_dict() for c in
                    self.ctx.commands_by_request.get(request_id, ())]
        return {"commands": cmds, "total": len(cmds)}

    def pending_commands(self) -> int:
        """Commands journaled but not yet applied (healthz: a wedged
        command plane shows up as this number growing)."""
        with self.ctx.lock:
            return sum(1 for c in self.ctx.commands.values() if c.pending)

    def wait_command(self, request_id: str, command_id: str,
                     timeout: float = 30.0) -> Dict[str, Any]:
        """Block until a command leaves ``pending`` (threaded mode)."""
        deadline = time.monotonic() + timeout
        while True:
            d = self.get_command(request_id, command_id)
            if d["status"] != "pending":
                return d
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"command {command_id} still pending after {timeout}s")
            time.sleep(0.01)

    def lookup_collection(self, name: str) -> Dict[str, Any]:
        return self.ctx.ddm.get_collection(name).to_dict()

    def lookup_contents(self, name: str) -> List[Dict[str, Any]]:
        return self.list_contents(name)["contents"]

    def list_contents(self, name: str, *, status: Optional[str] = None,
                      limit: Optional[int] = None,
                      offset: int = 0) -> Dict[str, Any]:
        """Per-file content catalog for one collection, with status
        filtering and limit/offset pagination (GET
        /v1/collections/<name>/contents)."""
        if status is not None and status not in CONTENT_STATUSES:
            raise ValueError(
                f"invalid status filter {status!r}; expected one of "
                f"{', '.join(CONTENT_STATUSES)}")
        if limit is not None and (isinstance(limit, bool)
                                  or not isinstance(limit, int)
                                  or limit < 0):
            raise ValueError("limit must be a non-negative integer")
        if isinstance(offset, bool) or not isinstance(offset, int) \
                or offset < 0:
            raise ValueError("offset must be a non-negative integer")
        rows = [f.to_dict() for f in self.ctx.ddm.get_collection(name).files
                if status is None or f.status == status]
        total = len(rows)
        end = None if limit is None else offset + limit
        return {"contents": rows[offset:end], "total": total,
                "limit": limit, "offset": offset}

    def list_collections(self) -> Dict[str, Any]:
        """Collection catalog: per-collection content tallies (GET
        /v1/collections)."""
        out = []
        for name in self.ctx.ddm.list_collections():
            c = self.ctx.ddm.get_collection(name)
            out.append({"name": c.name, "scope": c.scope,
                        "files": len(c.files),
                        "available": c.n_available,
                        "processed": c.n_processed,
                        "statuses": c.status_counts()})
        return {"collections": out, "total": len(out)}

    def content_stats(self) -> Dict[str, int]:
        """Per-status content tallies across every collection (healthz)."""
        out = {s: 0 for s in CONTENT_STATUSES}
        for name in self.ctx.ddm.list_collections():
            for s, n in self.ctx.ddm.get_collection(
                    name).status_counts().items():
                out[s] = out.get(s, 0) + n
        return out

    def transition_contents(self, name: str,
                            transitions: List[Dict[str, Any]]
                            ) -> Dict[str, Any]:
        """Bulk content state changes for one collection (POST
        /v1/collections/<name>/contents:transition — the Stager/
        Conductor hot path).  Each transition is ``{"name", "status"}``
        (plus optional ``size`` for rows registered on the fly).  The
        whole batch is validated up front (ValueError on any bad item);
        per item, the content rank guard decides ``applied``: a
        transition that would REGRESS the live row is skipped and
        reported, not errored.  Every applied row is journaled in ONE
        bulk store commit, and newly available files are announced on
        the bus so the Transformer's fine-grained dispatch sees them."""
        if not isinstance(transitions, list) or not transitions:
            raise ValueError("transitions (non-empty list) is required")
        for i, t in enumerate(transitions):
            if not isinstance(t, dict):
                raise ValueError(f"transitions[{i}] must be an object")
            if not t.get("name") or not isinstance(t["name"], str):
                raise ValueError(
                    f"transitions[{i}].name (string) is required")
            if t.get("status") not in CONTENT_STATUSES:
                raise ValueError(
                    f"transitions[{i}].status must be one of "
                    f"{', '.join(CONTENT_STATUSES)}")
        coll = self.ctx.ddm.get_collection(name)  # KeyError -> 404
        results: List[Dict[str, Any]] = []
        changed: List[Dict[str, Any]] = []
        became_available = False
        with self.ctx.lock:
            index = {f.name: f for f in coll.files}
            for t in transitions:
                fname, new_status = t["name"], t["status"]
                f = index.get(fname)
                if f is None:
                    # register-on-the-fly, honoring the requested status
                    f = FileRef(fname, size=int(t.get("size", 0) or 0),
                                status=new_status)
                    coll.files.append(f)
                    index[fname] = f
                if _content_rank(new_status) >= _content_rank(f.status):
                    f.set_status(new_status)
                    if new_status in ("available", "delivered"):
                        if not f.available and new_status == "available":
                            became_available = True
                        f.available = True
                    if new_status == "delivered":
                        f.processed = True
                    changed.append(f.to_dict())
                    results.append({"name": fname, "applied": True,
                                    "status": f.status})
                else:
                    results.append({"name": fname, "applied": False,
                                    "status": f.status})
        if changed:
            self.ctx.store.save_contents(name, changed)  # one bulk commit
            self.ctx.bump("contents_transitioned", len(changed))
            if became_available:
                self.ctx.bus.publish(M.T_COLLECTION_UPDATED,
                                     {"collection": name})
        return {"collection": name, "results": results,
                "applied": len(changed),
                "skipped": len(results) - len(changed)}

    # ------------------------------------------------------ delivery plane
    @staticmethod
    def _check_page(limit: Optional[int], offset: int) -> None:
        if limit is not None and (isinstance(limit, bool)
                                  or not isinstance(limit, int)
                                  or limit < 0):
            raise ValueError("limit must be a non-negative integer")
        if isinstance(offset, bool) or not isinstance(offset, int) \
                or offset < 0:
            raise ValueError("offset must be a non-negative integer")

    def subscribe(self, consumer: str,
                  collections: Optional[List[str]] = None, *,
                  sub_id: Optional[str] = None,
                  push_url: Optional[str] = None) -> Dict[str, Any]:
        """Register a consumer subscription: the Conductor will match
        every announced output content against it and track the
        resulting deliveries.  ``collections`` are exact names or
        fnmatch patterns (omit for all).  ``push_url`` switches the
        subscription to webhook mode: the Publisher POSTs delivery
        batches to it instead of waiting for the consumer to poll.
        Idempotent on a client-supplied ``sub_id`` (a retried POST
        returns the existing registration instead of subscribing
        twice)."""
        if not consumer or not isinstance(consumer, str):
            raise ValueError("consumer (string) is required")
        colls = list(collections or [])
        if not all(isinstance(c, str) and c for c in colls):
            raise ValueError("collections must be non-empty strings")
        if push_url is not None and (
                not isinstance(push_url, str)
                or not push_url.startswith(("http://", "https://"))):
            raise ValueError("push_url must be an http(s) URL")
        with self.ctx.lock:
            if sub_id and sub_id in self.ctx.subscriptions:
                return self.ctx.subscriptions[sub_id].summary()
            sub = Subscription(consumer=consumer, collections=colls,
                               push_url=push_url,
                               **({"sub_id": sub_id} if sub_id else {}))
            self.ctx.subscriptions[sub.sub_id] = sub
            d = sub.to_dict()
            summary = sub.summary()
        self.ctx.store.save_subscription(d)
        self.ctx.bump("subscriptions")
        return summary

    def list_subscriptions(self, *, limit: Optional[int] = None,
                           offset: int = 0) -> Dict[str, Any]:
        self._check_page(limit, offset)
        with self.ctx.lock:
            subs = [s.summary() for s in self.ctx.subscriptions.values()]
        total = len(subs)
        end = None if limit is None else offset + limit
        return {"subscriptions": subs[offset:end], "total": total,
                "limit": limit, "offset": offset}

    def get_subscription(self, sub_id: str) -> Dict[str, Any]:
        with self.ctx.lock:
            sub = self.ctx.subscriptions.get(sub_id)
            if sub is None:
                raise KeyError(f"unknown subscription {sub_id!r}")
            return sub.summary()

    def list_deliveries(self, sub_id: str, *,
                        status: Optional[str] = None,
                        limit: Optional[int] = None,
                        offset: int = 0) -> Dict[str, Any]:
        """A subscription's tracked deliveries, optionally filtered by
        status (notified/acked/failed) and paginated (``total`` counts
        the filtered set, not the page)."""
        if status is not None and status not in DELIVERY_STATUSES:
            raise ValueError(
                f"invalid status filter {status!r}; expected one of "
                f"{', '.join(DELIVERY_STATUSES)}")
        self._check_page(limit, offset)
        with self.ctx.lock:
            sub = self.ctx.subscriptions.get(sub_id)
            if sub is None:
                raise KeyError(f"unknown subscription {sub_id!r}")
            rows = [d.to_dict() for d in sub.deliveries.values()
                    if status is None or d.status == status]
        rows.sort(key=lambda d: (d["created_at"], d["delivery_id"]))
        total = len(rows)
        end = None if limit is None else offset + limit
        return {"deliveries": rows[offset:end], "total": total,
                "limit": limit, "offset": offset}

    def _on_notify(self, m: M.Message) -> None:
        """Bus subscriber on ``T_CONSUMER_NOTIFY``: wake parked
        long-poll/SSE handlers and stamp the publish time the
        publish-to-ack histogram measures from."""
        did = m.body.get("delivery_id")
        with self._delivery_cv:
            if did:
                # wall clock: the ack may land on another head
                self._publish_ts.setdefault(did, time.time())
            self._delivery_cv.notify_all()

    def wait_delivery_event(self, timeout: float) -> bool:
        """Park until the next consumer notification (or ``timeout``);
        the long-poll/SSE wake primitive.  True if woken."""
        with self._delivery_cv:
            return self._delivery_cv.wait(timeout=timeout)

    def wait_deliveries(self, sub_id: str, *,
                        status: Optional[str] = None,
                        limit: Optional[int] = None,
                        offset: int = 0,
                        wait_s: float = 0.0) -> Dict[str, Any]:
        """Long-poll variant of :meth:`list_deliveries`: returns
        immediately when the filtered listing is non-empty, otherwise
        parks on the delivery condition until a notification arrives or
        ``wait_s`` expires (then returns the — possibly empty — final
        listing)."""
        out = self.list_deliveries(sub_id, status=status, limit=limit,
                                   offset=offset)
        if out["deliveries"] or wait_s <= 0:
            return out
        deadline = time.monotonic() + wait_s
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return out
            # capped tick: a cross-head notification may reach this
            # head's bus between condition wakeups, so re-check even
            # without a wake
            self.wait_delivery_event(min(remaining, 0.25))
            out = self.list_deliveries(sub_id, status=status,
                                       limit=limit, offset=offset)
            if out["deliveries"]:
                return out

    def list_events(self, sub_id: str, *,
                    after_seq: Optional[int] = None,
                    limit: Optional[int] = None) -> Dict[str, Any]:
        """One subscription's journaled outbox rows ordered by the
        store-assigned ``seq`` — the SSE event source.  ``after_seq``
        is the resume cursor (``Last-Event-ID``): rows journaled while
        a consumer was disconnected are replayed from the journal, so a
        resumed stream misses nothing."""
        if after_seq is not None and (isinstance(after_seq, bool)
                                      or not isinstance(after_seq, int)
                                      or after_seq < 0):
            raise ValueError("after_seq must be a non-negative integer")
        self._check_page(limit, 0)
        with self.ctx.lock:
            if sub_id not in self.ctx.subscriptions:
                raise KeyError(f"unknown subscription {sub_id!r}")
        rows = self.ctx.store.load_messages(sub_id=sub_id,
                                            after_seq=after_seq,
                                            limit=limit)
        return {"events": rows, "total": len(rows)}

    def ack_delivery(self, sub_id: str,
                     delivery_ids: List[str]) -> Dict[str, Any]:
        """Consumer acknowledgement: mark deliveries received.  Once
        every subscription covering a content has acked it, the content
        itself turns ``delivered``.  Idempotent per delivery."""
        acked_contents: List[tuple] = []
        n = 0
        with self.ctx.lock:
            sub = self.ctx.subscriptions.get(sub_id)
            if sub is None:
                raise KeyError(f"unknown subscription {sub_id!r}")
            # validate the WHOLE batch before mutating anything: a bad
            # id must reject the request without leaving earlier
            # deliveries half-acked (acked in memory, never journaled,
            # and skipped by the idempotence check on a retry)
            targets = []
            for did in delivery_ids:
                d = sub.find_delivery(did)
                if d is None:
                    raise KeyError(f"unknown delivery {did!r} for "
                                   f"subscription {sub_id!r}")
                targets.append(d)
            for d in targets:
                if d.status == "acked":
                    continue
                d.set_status("acked")
                n += 1
                acked_contents.append(
                    (d.collection, d.file, d.delivery_id, d.created_at))
            snapshot = sub.to_dict()
        self.ctx.store.save_subscription(snapshot)
        if n:
            self.ctx.bump("deliveries_acked", n)
        now = time.time()
        for coll, fname, did, created_at in acked_contents:
            # wall-clock span: created_at was stamped by whichever head
            # first notified the consumer, possibly not this one
            self._ack_hist.observe(max(now - created_at, 0.0))
            with self._delivery_cv:
                pub_ts = self._publish_ts.pop(did, None)
            if pub_ts is not None:
                # Publisher fan-out -> consumer ack, as seen locally
                self._pub_ack_hist.observe(max(now - pub_ts, 0.0))
            self.ctx.trace("delivery_acked", collection=coll,
                           entity=did, data={"file": fname})
            self._maybe_content_delivered(coll, fname)
        return {"sub_id": sub_id, "acked": n}

    def _maybe_content_delivered(self, collection: str,
                                 file_name: str) -> None:
        """Flip an output content to ``delivered`` once every matching
        subscription has acked its delivery."""
        key = content_key(collection, file_name)
        with self.ctx.lock:
            subs = [s for s in self.ctx.subscriptions.values()
                    if s.matches(collection)]
            for s in subs:
                d = s.deliveries.get(key)
                if d is None or d.status != "acked":
                    return
        if not subs:
            return
        f = self.ctx.ddm.ensure_content(collection, file_name)
        f.set_status("delivered")
        self.ctx.store.save_contents(collection, [f.to_dict()])
        self.ctx.bump("contents_delivered")

    def delivery_stats(self) -> Dict[str, int]:
        """Delivery-plane tallies for healthz/operators."""
        out = {"subscriptions": 0}
        out.update({s: 0 for s in DELIVERY_STATUSES})
        with self.ctx.lock:
            for sub in self.ctx.subscriptions.values():
                out["subscriptions"] += 1
                for s, c in sub.counts().items():
                    out[s] = out.get(s, 0) + c
        return out

    @property
    def stats(self) -> Dict[str, int]:
        return dict(self.ctx.stats)

    # ------------------------------------------------------------- recovery
    def recover(self, *, workflow_ids: Optional[Set[str]] = None
                ) -> Dict[str, int]:
        """Reload persisted state from the store and re-enqueue whatever
        was in flight when the previous head service died.

        Call on a fresh instance over the same store *before* ``start()``
        or ``pump()`` — it publishes bus messages which the daemons then
        drain.  Idempotent: entities already known to this instance are
        skipped, so running it twice cannot duplicate works or
        processings.  Returns per-entity recovery counts.

        ``workflow_ids`` scopes the pass to those workflows (the
        Watchdog's adoption path: hydrate ONE dead head's workflow
        without touching live peers' state).  A scoped pass skips the
        cluster-shared planes — subscriptions (the Watchdog hydrates
        them separately) and lease orphan-dropping (peer heads' leases
        are alive, not orphans).
        """
        store = self.ctx.store
        counts = {"requests": 0, "workflows": 0, "works": 0,
                  "processings": 0, "collections": 0, "commands": 0,
                  "subscriptions": 0, "requeued_processings": 0,
                  "replayed_events": 0, "replayed_commands": 0,
                  "orphaned_leases": 0, "outbox_messages": 0}
        transformer = next(d for d in self.daemons
                           if isinstance(d, Transformer))
        new_wfs: List[Workflow] = []
        new_works: List[tuple] = []
        new_procs: List[Processing] = []
        procs_by_work: Dict[str, List[Processing]] = {}
        with self.ctx.lock:
            # collections first: dispatch decisions read availability
            for coll in store.load_collections():
                if coll["name"] in self._recovered_collections:
                    continue
                self._recovered_collections.add(coll["name"])
                self.ctx.ddm.register_collection(
                    coll["name"],
                    [FileRef.from_dict(f) for f in coll["files"]])
                counts["collections"] += 1
            for r in store.list_requests():
                if (workflow_ids is not None
                        and r.get("workflow_id") not in workflow_ids):
                    continue
                if r["request_id"] not in self._requests:
                    self._requests[r["request_id"]] = dict(r)
                    counts["requests"] += 1
                if r.get("workflow_id"):
                    self.ctx.request_of.setdefault(r["workflow_id"],
                                                   r["request_id"])
                    if r.get("trace_id"):
                        self.ctx.trace_ids.setdefault(r["workflow_id"],
                                                      r["trace_id"])
                    # rebuild the steering state the daemons gate on: a
                    # suspended/aborted request stays fenced across the
                    # restart until an operator resumes it
                    if r.get("status") in (CTRL_SUSPENDED, CTRL_ABORTED):
                        self.ctx.control[r["workflow_id"]] = r["status"]
            # delivery plane: subscriptions (with their embedded
            # delivery records) come back verbatim; a delivery
            # journaled `notified` is re-notified by the Conductor's
            # retry pass (its notification died with the old bus)
            if workflow_ids is None:
                for s in store.load_subscriptions():
                    if s["sub_id"] in self.ctx.subscriptions:
                        continue
                    self.ctx.subscriptions[s["sub_id"]] = \
                        Subscription.from_dict(s)
                    counts["subscriptions"] += 1
            new_cmds: List[Command] = []
            for c in store.load_commands():
                if (workflow_ids is not None
                        and c.get("workflow_id") not in workflow_ids):
                    continue
                if c["command_id"] in self.ctx.commands:
                    continue
                cmd = Command.from_dict(c)
                self.ctx.register_command(cmd)
                new_cmds.append(cmd)
                counts["commands"] += 1
            for d in store.load_workflows():
                if (workflow_ids is not None
                        and d["workflow_id"] not in workflow_ids):
                    continue
                if d["workflow_id"] in self.ctx.workflows:
                    continue
                wf = Workflow.from_dict(d)
                self.ctx.workflows[wf.workflow_id] = wf
                new_wfs.append(wf)
                counts["workflows"] += 1
            for wf_id, wd in store.load_works():
                wf = self.ctx.workflows.get(wf_id)
                if wf is None or wd["work_id"] in wf.works:
                    continue
                w = Work.from_dict(wd)
                wf.works[w.work_id] = w
                self.ctx.works[w.work_id] = (wf_id, w)
                new_works.append((wf_id, w))
                counts["works"] += 1
            for pd in store.load_processings():
                if pd["work_id"] not in self.ctx.works:
                    # a peer head's processing (scoped pass), or a row
                    # with no journaled work — never requeue those here
                    continue
                if pd["proc_id"] in self.ctx.processings:
                    p = self.ctx.processings[pd["proc_id"]]
                else:
                    p = Processing.from_dict(pd)
                    self.ctx.processings[p.proc_id] = p
                    new_procs.append(p)
                    counts["processings"] += 1
                procs_by_work.setdefault(p.work_id, []).append(p)
            # any workflow with works already ran wf.start(); mark it so
            # replayed T_NEW_WORKFLOWS messages cannot re-instantiate
            for wf in new_wfs:
                if wf.works:
                    self.ctx.started_workflows.add(wf.workflow_id)
        if workflow_ids is None:
            # full recovery asserts this head is THE head now: claims
            # held by the dead predecessor are stale by definition, so
            # take them over without waiting out their TTL.  (A scoped
            # adoption pass never does this — the Watchdog only adopts
            # claims that already expired.)
            stale = {c["entity_id"]: c
                     for c in store.list_claims("workflow")}
            for wf in new_wfs:
                c = stale.get(wf.workflow_id)
                if c is not None and c["owner_id"] != self.ctx.head_id:
                    store.release_claim("workflow", wf.workflow_id,
                                        c["owner_id"])
                self.ctx.try_own(wf.workflow_id)
        # publishes happen outside ctx.lock (bus subscribers may take it)
        for wf in new_wfs:
            if not wf.works:
                # journaled at submit but the Marshaller never started it
                self.ctx.bus.publish(M.T_NEW_WORKFLOWS, {
                    "workflow_id": wf.workflow_id, "request_id": None})
                counts["replayed_events"] += 1
        for wf_id, w in new_works:
            if w.status.terminated:
                if not w.condition_evaluated:
                    # finalized pre-crash, but its T_WORK_DONE died with
                    # the old process: replay the event (the Marshaller
                    # then evaluates conditions exactly once)
                    self.ctx.inflight_add(wf_id, 1)
                    self.ctx.bus.publish(M.T_WORK_DONE,
                                         {"work_id": w.work_id,
                                          "workflow_id": wf_id})
                    counts["replayed_events"] += 1
            else:
                transformer.restore(w, procs_by_work.get(w.work_id, []))
        for p in new_procs:
            if p.terminal:
                continue
            if p.status == ProcessingStatus.FAILED:
                # journaled mid-retry (attempt failed, retries left):
                # consume the failed attempt exactly as the Carrier's
                # retry path would have
                p.attempt += 1
            # the grid job (if any) died with the old WFM: resubmit,
            # preserving the attempt count
            p.status = ProcessingStatus.NEW
            p.error = None
            store.save_processing(p.to_dict())
            self.ctx.bus.publish(M.T_NEW_PROCESSINGS,
                                 {"proc_id": p.proc_id,
                                  "workflow_id":
                                      self.ctx.works[p.work_id][0]})
            counts["requeued_processings"] += 1
        # leases journaled by the old head's scheduler are orphans: the
        # jobs they covered were requeued above (non-terminal processings
        # are re-announced), the new scheduler starts with an empty lease
        # table, and a stale worker reporting against the dead lease gets
        # a 409 — so dropping the rows is the whole requeue.  Scoped
        # adoption must NOT do this: peer heads' leases are live.
        if workflow_ids is None:
            for row in store.load_leases():
                store.delete_lease(row["job_id"])
                counts["orphaned_leases"] += 1
        # outbox rows journaled but not yet delivered (or mid-retry)
        # survive verbatim in the messages table — the Publisher drains
        # them by store query, so recovery only needs to count them and
        # nudge the wake topic (losing the nudge would merely cost one
        # poll interval of latency).  This is the crash-loss class the
        # transactional outbox closes: the notification either never
        # committed (its delivery didn't either) or is still here.
        if workflow_ids is None:
            undelivered = store.count_messages(
                statuses=UNDELIVERED_STATUSES)
            if undelivered:
                counts["outbox_messages"] = undelivered
                self.ctx.bus.publish(M.T_OUTBOX, {"count": undelivered})
        # commands journaled pending but never applied (or applied but
        # not journaled done) died with the old Commander: replay them.
        # Applying is idempotent against already-reflected state, so the
        # effect of each command happens exactly once across restarts.
        for cmd in new_cmds:
            if cmd.pending:
                self.ctx.bus.publish(M.T_NEW_COMMANDS,
                                     {"command_id": cmd.command_id,
                                      "request_id": cmd.request_id,
                                      "workflow_id": cmd.workflow_id})
                counts["replayed_commands"] += 1
        return counts

    def _adopt_workflow(self, workflow_id: str) -> int:
        """Watchdog adoption callback: claim-aware scoped recovery of
        one workflow whose previous head died.  Returns how many
        entities/events were restored (0 when everything was already
        live, so a pump can quiesce)."""
        counts = self.recover(workflow_ids={workflow_id})
        n = sum(counts.values())
        if n:
            self.ctx.bump("workflows_adopted")
            self.ctx.trace("workflow_adopted",
                           request_id=self.ctx.request_of.get(workflow_id),
                           trace_id=self.ctx.trace_id_of(workflow_id),
                           data={"restored": n})
        return n

    # ---------------------------------------------------------- observability
    def trace(self, request_id: str) -> Dict[str, Any]:
        """Reconstruct a request's lifecycle timeline from journaled
        trace events (GET /v1/requests/<id>/trace).  Events keyed by
        the request's works' input/output collections (staging and
        delivery hops) are joined in, so the timeline spans every head
        that touched the request."""
        info = self.request_status(request_id)  # KeyError -> 404
        colls: Set[str] = set()
        wf = self.ctx.workflows.get(info["workflow_id"])
        if wf is not None:
            with self.ctx.lock:
                for w in wf.works.values():
                    if w.input_collection:
                        colls.add(w.input_collection)
                    if w.output_collection:
                        colls.add(w.output_collection)
        events = self.ctx.store.load_trace_events(
            request_id=request_id,
            collections=sorted(colls) or None)
        out = build_trace(events)
        out["request_id"] = request_id
        out["status"] = info.get("status")
        return out

    def metrics_text(self, *, cluster: bool = False) -> str:
        """Prometheus text exposition (GET /v1/metrics).  With
        ``cluster=True``, merge in the metrics snapshots live peer
        heads heartbeat into the health table, each series tagged with
        its ``head`` label."""
        snaps = [self.metrics.snapshot()]
        if cluster:
            now = time.time()
            for h in self.ctx.store.load_health():
                if h["head_id"] == self.ctx.head_id:
                    continue  # serve our own registry live, not a snapshot
                if now - h["last_heartbeat"] >= self.ctx.claim_ttl:
                    continue  # dead head: its snapshot is stale
                snap = (h.get("data") or {}).get("metrics")
                if snap:
                    snaps.append(snap)
        return render_snapshots(snaps)

    # -------------------------------------------------------------- cluster
    def cluster_info(self) -> Dict[str, Any]:
        """The cluster as observed through the shared store: every head
        that heartbeated the health table, its heartbeat age, and the
        live (unexpired) workflow claims per head (GET /v1/cluster).
        A head is reported alive while its heartbeat is younger than
        the claim TTL — the horizon after which its claims become
        stealable anyway."""
        now = time.time()
        by_owner: Dict[str, int] = {}
        for c in self.ctx.store.list_claims("workflow"):
            if c["claimed_until"] >= now:
                by_owner[c["owner_id"]] = by_owner.get(c["owner_id"], 0) + 1
        heads = []
        for h in self.ctx.store.load_health():
            age = max(0.0, now - h["last_heartbeat"])
            data = dict(h.get("data") or {})
            # the embedded metrics snapshot is for /v1/metrics?cluster=1;
            # it would dwarf the membership view served here
            data.pop("metrics", None)
            heads.append({
                "head_id": h["head_id"],
                "started_at": h["started_at"],
                "last_heartbeat": h["last_heartbeat"],
                "heartbeat_age_s": round(age, 3),
                "alive": age < self.ctx.claim_ttl,
                "claims": by_owner.get(h["head_id"], 0),
                "data": data,
            })
        heads.sort(key=lambda h: h["head_id"])
        return {"head_id": self.ctx.head_id,
                "bus": getattr(self.ctx.bus, "name", "local"),
                "claim_ttl": self.ctx.claim_ttl,
                "heads": heads, "total": len(heads),
                "claims": sum(by_owner.values())}

    # --------------------------------------------------------------- execution
    def pump(self, max_rounds: int = 100_000) -> int:
        """Cycle daemons until quiescent. Returns #rounds executed."""
        for i in range(max_rounds):
            moved = sum(d.process_once() for d in self.daemons)
            if moved == 0:
                return i + 1
        raise RuntimeError(f"pump did not quiesce in {max_rounds} rounds")

    def pump_until(self, cond: Callable[[], bool], *,
                   timeout: float = 60.0, interval: float = 0.0) -> None:
        """Pump until ``cond()`` — for incremental-availability scenarios
        where external events (staging) interleave with daemon cycles."""
        deadline = time.monotonic() + timeout
        while not cond():
            moved = sum(d.process_once() for d in self.daemons)
            if moved == 0:
                if time.monotonic() > deadline:
                    raise TimeoutError("pump_until timed out")
                if interval:
                    time.sleep(interval)

    def start(self) -> None:
        """Production mode: one thread per daemon."""
        self._stop.clear()
        for d in self.daemons:
            t = threading.Thread(target=d.run_forever, args=(self._stop,),
                                 name=f"idds-{d.name}", daemon=True)
            t.start()
            self._threads.append(t)

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5)
        self._threads.clear()
        self.ctx.wfm.shutdown()

    def close(self) -> None:
        """Graceful teardown: stop the daemons, release this head's
        workflow claims (a peer head can adopt immediately instead of
        waiting out the TTL), stop any DDM staging pools, then close
        the store."""
        if self._threads:
            self.stop()
        with self.ctx.lock:
            owned = list(self.ctx.claimed)
        for wf_id in owned:
            self.ctx.disown(wf_id)
        shut = getattr(self.ctx.ddm, "shutdown", None)
        if callable(shut):
            shut()
        self.ctx.store.close()

    def wait_request(self, request_id: str, timeout: float = 60.0) -> Dict:
        """Block until a request's workflow reaches a terminal state —
        finished, or aborted by a command (threaded mode)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            info = self.request_status(request_id)
            if info.get("status") in ("finished", "aborted"):
                return info
            time.sleep(0.01)
        raise TimeoutError(f"request {request_id} not finished in {timeout}s")
