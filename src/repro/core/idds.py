"""iDDS head service: the RESTful facade + daemon runner.

Authenticates users, registers and queries requests, and provides an
interface to look up data collections/contents (paper §2).  Two execution
modes:

  * ``pump()``      — deterministic: cycle the daemons until the system is
                      quiescent (unit tests, simulators);
  * ``start()/stop()`` — production: one thread per daemon + threaded WFM
                      pool, requests served concurrently.

The HTTP layer is intentionally thin (a real deployment puts Flask/nginx
in front); every entry point already speaks JSON strings, so the daemons
never see Python objects from the client.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Set

from repro.core import messaging as M
from repro.core.daemons import (ALL_DAEMONS, Carrier, Clerk, Conductor,
                                Context, Marshaller, Transformer, WFMExecutor)
from repro.core.ddm import DDM, InMemoryDDM
from repro.core.requests import Request
from repro.core.workflow import Workflow


class AuthError(Exception):
    pass


class IDDS:
    def __init__(self, *, ddm: Optional[DDM] = None, sync: bool = True,
                 max_workers: int = 8,
                 fault_hook: Optional[Callable] = None,
                 tokens: Optional[Set[str]] = None):
        bus = M.MessageBus()
        self.ctx = Context(
            bus=bus,
            ddm=ddm if ddm is not None else InMemoryDDM(),
            wfm=WFMExecutor(sync=sync, max_workers=max_workers,
                            fault_hook=fault_hook),
        )
        self.daemons = [cls(self.ctx) for cls in ALL_DAEMONS]
        self._tokens = tokens  # None -> auth disabled (dev mode)
        self._requests: Dict[str, Dict[str, Any]] = {}
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()

    # ------------------------------------------------------------------ auth
    def _auth(self, token: str) -> None:
        if self._tokens is not None and token not in self._tokens:
            raise AuthError("invalid token")

    # --------------------------------------------------------------- client API
    def submit(self, request_json: str) -> str:
        """Accept a serialized Request; returns the request_id.

        Idempotent on request_id: resubmitting an already-registered
        request (an HTTP client retrying after a lost response) is a
        no-op, so the workflow never runs twice.
        """
        req = Request.from_json(request_json)
        self._auth(req.token)
        with self.ctx.lock:
            if req.request_id in self._requests:
                return req.request_id
            self._requests[req.request_id] = {
                "request_id": req.request_id,
                "workflow_id": req.workflow.workflow_id,
                "requester": req.requester,
                "status": "accepted",
                "submitted_at": time.time(),
            }
        self.ctx.bus.publish(M.T_NEW_REQUESTS, {
            "request_id": req.request_id,
            "workflow": req.workflow.to_json(),
        })
        return req.request_id

    def submit_workflow(self, wf: Workflow, requester: str = "anonymous",
                        token: str = "") -> str:
        return self.submit(Request(workflow=wf, requester=requester,
                                   token=token).to_json())

    def request_status(self, request_id: str) -> Dict[str, Any]:
        info = dict(self._requests[request_id])
        wf = self.ctx.workflows.get(info["workflow_id"])
        if wf is not None:
            # snapshot under ctx.lock: daemon threads insert into wf.works
            # (iteration would race), and finished+quiescent must be read
            # against the same instant or a poll between the Marshaller's
            # successor-instantiation and its inflight decrement could
            # still report a false "finished"
            with self.ctx.lock:
                info["works"] = wf.counts()
                done = wf.finished and self.ctx.quiescent(wf.workflow_id)
            info["status"] = "finished" if done else "running"
        return info

    def get_workflow(self, request_id: str) -> Workflow:
        return self.ctx.workflows[self._requests[request_id]["workflow_id"]]

    def workflow_dict(self, request_id: str) -> Dict[str, Any]:
        """Serialized workflow snapshot, safe against live daemon threads."""
        wf = self.get_workflow(request_id)
        with self.ctx.lock:
            return wf.to_dict()

    def lookup_collection(self, name: str) -> Dict[str, Any]:
        return self.ctx.ddm.get_collection(name).to_dict()

    def lookup_contents(self, name: str) -> List[Dict[str, Any]]:
        return [f.to_dict() for f in self.ctx.ddm.get_collection(name).files]

    @property
    def stats(self) -> Dict[str, int]:
        return dict(self.ctx.stats)

    # --------------------------------------------------------------- execution
    def pump(self, max_rounds: int = 100_000) -> int:
        """Cycle daemons until quiescent. Returns #rounds executed."""
        for i in range(max_rounds):
            moved = sum(d.process_once() for d in self.daemons)
            if moved == 0:
                return i + 1
        raise RuntimeError(f"pump did not quiesce in {max_rounds} rounds")

    def pump_until(self, cond: Callable[[], bool], *,
                   timeout: float = 60.0, interval: float = 0.0) -> None:
        """Pump until ``cond()`` — for incremental-availability scenarios
        where external events (staging) interleave with daemon cycles."""
        deadline = time.time() + timeout
        while not cond():
            moved = sum(d.process_once() for d in self.daemons)
            if moved == 0:
                if time.time() > deadline:
                    raise TimeoutError("pump_until timed out")
                if interval:
                    time.sleep(interval)

    def start(self) -> None:
        """Production mode: one thread per daemon."""
        self._stop.clear()
        for d in self.daemons:
            t = threading.Thread(target=d.run_forever, args=(self._stop,),
                                 name=f"idds-{d.name}", daemon=True)
            t.start()
            self._threads.append(t)

    def stop(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5)
        self._threads.clear()
        self.ctx.wfm.shutdown()

    def wait_request(self, request_id: str, timeout: float = 60.0) -> Dict:
        """Block until a request's workflow finishes (threaded mode)."""
        deadline = time.time() + timeout
        while time.time() < deadline:
            info = self.request_status(request_id)
            if info.get("status") == "finished":
                return info
            time.sleep(0.01)
        raise TimeoutError(f"request {request_id} not finished in {timeout}s")
