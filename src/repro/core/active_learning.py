"""Active-Learning workflow (paper §3.3.2, Fig. 7).

Two Work template kinds: *processing* and *decision making*.  The decision
Work takes output data from the upstream processing Work and provides
hints to the downstream processing Work.  When a Work completes, its
Condition branches are evaluated to decide whether to trigger the next
processing, and with what new parameter values — a DG **cycle** bounded by
``max_iterations``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.core import payloads as reg
from repro.core.spec import WorkflowSpec
from repro.core.workflow import Workflow


@reg.register_binder("al_pass_result")
def _al_pass_result(params: Dict[str, Any], result) -> Dict[str, Any]:
    """decision -> next processing: apply the decision's hints."""
    out = dict(params)
    out.update((result or {}).get("hint", {}))
    out["round"] = int(out.get("round", 0)) + 1
    return out


@reg.register_binder("al_to_decision")
def _al_to_decision(params: Dict[str, Any], result) -> Dict[str, Any]:
    """processing -> decision: forward params + processing outputs."""
    out = dict(params)
    out["processing_result"] = dict(result or {})
    return out


@reg.register_predicate("al_continue")
def _al_continue(work, result) -> bool:
    return bool((result or {}).get("decision", False))


def build_active_learning_workflow(
    *,
    process_payload: str,
    decide_payload: str,
    init_params: Optional[Dict[str, Any]] = None,
    max_iterations: int = 10,
    name: str = "active-learning",
    input_collection: Optional[str] = None,
) -> Workflow:
    """process --always--> decide --(decision==True)--> process (cycle)."""
    spec = WorkflowSpec(name)
    process = spec.work("process", payload=process_payload,
                        input_collection=input_collection,
                        granularity="fine",
                        start={"round": 0, **(init_params or {})})
    decide = spec.work("decide", payload=decide_payload)
    process.then(decide, binder="al_to_decision",
                 max_iterations=2 * max_iterations + 1)
    # a false verdict ends the loop: no `otherwise` branch, no new works
    decide.when("al_continue",
                then=[(process, "al_pass_result")],
                max_iterations=2 * max_iterations)
    return spec.build()
