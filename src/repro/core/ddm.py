"""DDM-system boundary (the paper's Rucio side).

iDDS daemons talk to a DDM through this narrow interface; the carousel
package provides the production implementation (ColdStore + DiskCache +
Stager).  ``InMemoryDDM`` backs unit tests and the pure-orchestration use
cases (HPO, Rubin DAGs) whose collections are virtual.

Every per-file mutation also advances the content state machine
(``FileRef.status``: new -> staging -> available -> delivered | failed)
so the delivery plane can journal and expose per-file state.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Protocol

from repro.core.workflow import Collection, FileRef


class DDM(Protocol):
    def get_collection(self, name: str) -> Collection: ...
    def list_collections(self) -> List[str]: ...
    def register_collection(self, name: str,
                            files: Iterable[FileRef]) -> Collection: ...
    def set_available(self, name: str, file_name: str,
                      available: bool = True) -> None: ...
    def mark_processed(self, name: str, file_name: str) -> None: ...

    def ensure_content(self, name: str, file_name: str,
                       size: int = 0) -> FileRef:
        """Register-or-mark-available one content (the Conductor calls
        this for freshly announced outputs)."""
        ...


class InMemoryDDM:
    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._collections: Dict[str, Collection] = {}

    def get_collection(self, name: str) -> Collection:
        with self._lock:
            if name not in self._collections:
                # virtual collection: a single, immediately-available token
                self._collections[name] = Collection(
                    name, files=[FileRef(f"{name}#0", size=0, available=True)])
            return self._collections[name]

    def list_collections(self) -> List[str]:
        with self._lock:
            return list(self._collections)

    def register_collection(self, name: str,
                            files: Iterable[FileRef]) -> Collection:
        with self._lock:
            c = Collection(name, files=list(files))
            self._collections[name] = c
            return c

    def set_available(self, name: str, file_name: str,
                      available: bool = True) -> None:
        with self._lock:
            for f in self._collections[name].files:
                if f.name == file_name:
                    f.available = available
                    f.set_status("available" if available else "new")
                    return
            raise KeyError(file_name)

    def ensure_content(self, name: str, file_name: str,
                       size: int = 0) -> FileRef:
        with self._lock:
            coll = self._collections.get(name)
            if coll is None:
                # output collections materialize lazily, initially empty
                coll = self._collections[name] = Collection(name)
            for f in coll.files:
                if f.name == file_name:
                    if not f.available:
                        f.available = True
                        f.set_status("available")
                    return f
            f = FileRef(file_name, size=size, available=True)
            coll.files.append(f)
            return f

    def mark_processed(self, name: str, file_name: str) -> None:
        with self._lock:
            for f in self._collections[name].files:
                if f.name == file_name:
                    f.processed = True
                    # the input content was delivered to (and consumed
                    # by) its processing — a terminal content state
                    f.set_status("delivered")
                    return
            raise KeyError(file_name)
