"""DDM-system boundary (the paper's Rucio side).

iDDS daemons talk to a DDM through this narrow interface; the carousel
package provides the production implementation (ColdStore + DiskCache +
Stager).  ``InMemoryDDM`` backs unit tests and the pure-orchestration use
cases (HPO, Rubin DAGs) whose collections are virtual.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, Protocol

from repro.core.workflow import Collection, FileRef


class DDM(Protocol):
    def get_collection(self, name: str) -> Collection: ...
    def register_collection(self, name: str,
                            files: Iterable[FileRef]) -> Collection: ...
    def set_available(self, name: str, file_name: str,
                      available: bool = True) -> None: ...
    def mark_processed(self, name: str, file_name: str) -> None: ...


class InMemoryDDM:
    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._collections: Dict[str, Collection] = {}

    def get_collection(self, name: str) -> Collection:
        with self._lock:
            if name not in self._collections:
                # virtual collection: a single, immediately-available token
                self._collections[name] = Collection(
                    name, files=[FileRef(f"{name}#0", size=0, available=True)])
            return self._collections[name]

    def register_collection(self, name: str,
                            files: Iterable[FileRef]) -> Collection:
        with self._lock:
            c = Collection(name, files=list(files))
            self._collections[name] = c
            return c

    def set_available(self, name: str, file_name: str,
                      available: bool = True) -> None:
        with self._lock:
            for f in self._collections[name].files:
                if f.name == file_name:
                    f.available = available
                    return
            raise KeyError(file_name)

    def mark_processed(self, name: str, file_name: str) -> None:
        with self._lock:
            for f in self._collections[name].files:
                if f.name == file_name:
                    f.processed = True
                    return
            raise KeyError(file_name)
