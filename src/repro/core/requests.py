"""Client-side request objects + the JSON boundary of paper Fig. 2.

Clients define Workflows, serialize them into json-based requests, and
submit them to the RESTful head service; the server deserializes and
passes them to the daemons.  ``Request.to_json`` / ``from_json`` IS that
boundary — tests assert the round trip is lossless.
"""
from __future__ import annotations

import json
import time
import uuid
from dataclasses import dataclass, field

from repro.core.workflow import Workflow


@dataclass
class Request:
    workflow: Workflow
    requester: str = "anonymous"
    token: str = ""
    request_id: str = field(
        default_factory=lambda: f"req-{uuid.uuid4().hex[:12]}")
    created_at: float = field(default_factory=time.time)
    status: str = "new"  # new | accepted | running | finished | failed

    def to_json(self) -> str:
        return json.dumps({
            "request_id": self.request_id,
            "requester": self.requester,
            "token": self.token,
            "created_at": self.created_at,
            "status": self.status,
            "workflow": self.workflow.to_dict(),
        }, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "Request":
        d = json.loads(s)
        return cls(
            workflow=Workflow.from_dict(d["workflow"]),
            requester=d.get("requester", "anonymous"),
            token=d.get("token", ""),
            request_id=d["request_id"],
            created_at=d.get("created_at", time.time()),
            status=d.get("status", "new"),
        )
