"""Hyperparameter-Optimization service (paper §3.2, Fig. 6).

iDDS *centrally* scans the search space with an optimization algorithm to
generate hyperparameter points; points are evaluated *asynchronously* on
remote resources (here: the WFM worker pool standing in for grid/HPC/cloud
GPUs); results are reported back to refine the search and emit the next
round of points.  The user gets the best point + all trial records.

Experiment-agnostic: the evaluation payload is any registered payload that
returns {"objective": float}.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.idds import IDDS
from repro.core.spec import WorkflowSpec
from repro.core.workflow import Workflow


# ---------------------------------------------------------------------------
# Search space
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Dim:
    kind: str                   # uniform | loguniform | int | choice
    lo: float = 0.0
    hi: float = 1.0
    choices: Tuple[Any, ...] = ()

    def sample(self, u: float) -> Any:
        """Map u in [0,1) into the dimension."""
        if self.kind == "uniform":
            return self.lo + u * (self.hi - self.lo)
        if self.kind == "loguniform":
            return math.exp(math.log(self.lo)
                            + u * (math.log(self.hi) - math.log(self.lo)))
        if self.kind == "int":
            return int(self.lo + u * (self.hi - self.lo + 1))
        if self.kind == "choice":
            return self.choices[min(int(u * len(self.choices)),
                                    len(self.choices) - 1)]
        raise ValueError(self.kind)


def uniform(lo, hi):
    return Dim("uniform", lo, hi)


def loguniform(lo, hi):
    return Dim("loguniform", lo, hi)


def integer(lo, hi):
    return Dim("int", lo, hi)


def choice(*opts):
    return Dim("choice", choices=tuple(opts))


SearchSpace = Dict[str, Dim]


# ---------------------------------------------------------------------------
# Optimizers (ask/tell)
# ---------------------------------------------------------------------------


class Optimizer:
    def __init__(self, space: SearchSpace, seed: int = 0):
        self.space = space
        self.rnd = random.Random(seed)
        self.trials: List[Tuple[Dict[str, Any], float]] = []

    def ask(self, n: int) -> List[Dict[str, Any]]:
        raise NotImplementedError

    def tell(self, point: Dict[str, Any], objective: float) -> None:
        self.trials.append((dict(point), float(objective)))

    @property
    def best(self) -> Tuple[Optional[Dict[str, Any]], float]:
        if not self.trials:
            return None, math.inf
        return min(self.trials, key=lambda t: t[1])


class RandomSearch(Optimizer):
    def ask(self, n: int) -> List[Dict[str, Any]]:
        return [{k: d.sample(self.rnd.random()) for k, d in self.space.items()}
                for _ in range(n)]


def _halton(i: int, base: int) -> float:
    f, r = 1.0, 0.0
    while i > 0:
        f /= base
        r += f * (i % base)
        i //= base
    return r


class HaltonSearch(Optimizer):
    """Quasi-random low-discrepancy scan — better coverage than random for
    the first O(100) points."""
    _PRIMES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)

    def __init__(self, space: SearchSpace, seed: int = 0):
        super().__init__(space, seed)
        self._i = 1 + seed * 1000

    def ask(self, n: int) -> List[Dict[str, Any]]:
        out = []
        keys = list(self.space)
        for _ in range(n):
            u = {k: _halton(self._i, self._PRIMES[j % len(self._PRIMES)])
                 for j, k in enumerate(keys)}
            out.append({k: self.space[k].sample(u[k]) for k in keys})
            self._i += 1
        return out


class GaussianEvolution(Optimizer):
    """Exploit/explore: half the batch samples Gaussian perturbations of the
    elite trials (in the unit cube), half stays random — a small, honest
    'advanced optimization algorithm' whose refinement demonstrably beats
    random search on smooth objectives (see benchmarks/hpo_bench.py)."""

    def __init__(self, space: SearchSpace, seed: int = 0, sigma: float = 0.15,
                 elite_frac: float = 0.25):
        super().__init__(space, seed)
        self.sigma = sigma
        self.elite_frac = elite_frac
        # point-key -> unit coords
        self._unit: Dict[str, Dict[str, float]] = {}

    def _sample_unit(self) -> Dict[str, float]:
        return {k: self.rnd.random() for k in self.space}

    def _to_point(self, u: Dict[str, float]) -> Dict[str, Any]:
        return {k: self.space[k].sample(min(max(u[k], 0.0), 1 - 1e-9))
                for k in self.space}

    def ask(self, n: int) -> List[Dict[str, Any]]:
        elites = sorted(self.trials, key=lambda t: t[1])
        elites = elites[:max(1, int(len(elites) * self.elite_frac))]
        out = []
        for i in range(n):
            if self.trials and i % 2 == 0:
                base, _ = self.rnd.choice(elites)
                key = repr(sorted(base.items()))
                u0 = self._unit.get(key) or self._sample_unit()
                u = {k: u0[k] + self.rnd.gauss(0, self.sigma) for k in u0}
            else:
                u = self._sample_unit()
            p = self._to_point(u)
            self._unit[repr(sorted(p.items()))] = u
            out.append(p)
        return out

    def tell(self, point, objective):
        super().tell(point, objective)


OPTIMIZERS = {
    "random": RandomSearch,
    "halton": HaltonSearch,
    "evolution": GaussianEvolution,
}


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------


@dataclass
class HPOResult:
    best_point: Dict[str, Any]
    best_objective: float
    trials: List[Tuple[Dict[str, Any], float]]
    rounds: int
    failed_trials: int = 0


class HPOService:
    """Round-based central scan with asynchronous remote evaluation."""

    def __init__(self, idds: IDDS, space: SearchSpace, *,
                 eval_payload: str, optimizer: str = "evolution",
                 points_per_round: int = 8, max_points: int = 64,
                 seed: int = 0, extra_params: Optional[Dict[str, Any]] = None):
        self.idds = idds
        self.space = space
        self.opt: Optimizer = OPTIMIZERS[optimizer](space, seed=seed)
        self.eval_payload = eval_payload
        self.points_per_round = points_per_round
        self.max_points = max_points
        self.extra = dict(extra_params or {})
        self.failed = 0

    def _round_workflow(self, points: List[Dict[str, Any]],
                        rnd: int) -> Workflow:
        spec = WorkflowSpec(f"hpo-round-{rnd}")
        spec.work("evaluate", payload=self.eval_payload, max_attempts=2,
                  start=[{**self.extra, **p, "_hpo_round": rnd,
                          "_hpo_idx": i} for i, p in enumerate(points)])
        return spec.build()

    def run(self, *, sync: Optional[bool] = None,
            timeout: float = 300.0) -> HPOResult:
        evaluated = 0
        rnd = 0
        sync = self.idds.ctx.wfm.sync if sync is None else sync
        while evaluated < self.max_points:
            n = min(self.points_per_round, self.max_points - evaluated)
            points = self.opt.ask(n)
            wf = self._round_workflow(points, rnd)
            req = self.idds.submit_workflow(wf, requester="hpo")
            if sync:
                self.idds.pump()
            else:
                self.idds.wait_request(req, timeout=timeout)
            # report results back to the central optimizer (the server-side
            # workflow: the client copy never crosses the JSON boundary)
            server_wf = self.idds.get_workflow(req)
            for w in server_wf.works.values():
                res = w.result or {}
                if "objective" in res:
                    point = {k: w.params[k] for k in self.space}
                    self.opt.tell(point, res["objective"])
                else:
                    self.failed += 1
            evaluated += n
            rnd += 1
        best_point, best_obj = self.opt.best
        return HPOResult(best_point=best_point, best_objective=best_obj,
                         trials=list(self.opt.trials), rounds=rnd,
                         failed_trials=self.failed)
