"""Typed client SDK for the iDDS REST gateway (paper §2, client side).

``IDDSClient`` mirrors the in-process :class:`repro.core.idds.IDDS`
facade method-for-method, but speaks HTTP to a :class:`repro.core.rest.
RestGateway` — always through the versioned ``/v1`` namespace (the
unversioned paths are deprecated aliases kept for old clients).  Error
mapping preserves in-process semantics so callers can swap one for the
other:

  HTTP 401  -> repro.core.idds.AuthError
  HTTP 404  -> KeyError
  HTTP 409  -> ConflictError (stale/expired lease or lifecycle-command
               conflict; never retried)
  other 4xx -> IDDSClientError (no retry)
  5xx / connection errors -> retried with jittered exponential backoff
               *only for idempotent calls*, then IDDSClientError; a
               non-idempotent call fails immediately (a blind retry
               after a lost response could apply it twice)

Every GET is idempotent.  POSTs are retried only where a retry is
provably safe: POST /requests deduplicates server-side on the
client-generated request_id; POST /jobs/lease carries a client-supplied
idempotency key so a retried lease returns the same job instead of
leasing a second one; heartbeat renewal and completion are deduplicated
per (job, worker) on the server.

Only the stdlib (``urllib``) is used — no extra dependencies.

    client = IDDSClient("http://127.0.0.1:8443", token="s3cret")
    rid = client.submit_workflow(wf, requester="alice")
    info = client.wait(rid, timeout=60)
"""
from __future__ import annotations

import json
import random
import time
import urllib.error
import urllib.parse
import urllib.request
import uuid
from typing import Any, Dict, List, Optional

from repro.core.idds import AuthError
from repro.core.requests import Request
from repro.core.workflow import Workflow


class IDDSClientError(Exception):
    """Non-auth, non-404 gateway error (carries HTTP status + server type)."""

    def __init__(self, status: int, type_: str, message: str):
        super().__init__(f"HTTP {status} [{type_}]: {message}")
        self.status = status
        self.type = type_


class ConflictError(IDDSClientError):
    """HTTP 409: lease validation failed (expired or held by another
    worker).  The server state did not change; retrying verbatim cannot
    succeed, so the worker should drop the job and lease a fresh one."""

    def __init__(self, message: str):
        super().__init__(409, "Conflict", message)


# the stable API namespace every SDK call goes through
API_PREFIX = "/v1"


class BatchResult(dict):
    """Typed view of the unified batch envelope every batch verb
    returns (``jobs/heartbeat``, ``jobs/complete``,
    ``contents:transition`` — see ``repro.core.rest.batch_envelope``).

    A ``dict`` subclass: existing callers that index the raw envelope
    (``out["results"]``, ``out.get("ok")``) keep working unchanged,
    while new code gets attributes and per-item partitions."""

    @property
    def results(self) -> List[Dict[str, Any]]:
        return self.get("results", [])

    @property
    def ok_count(self) -> int:
        return int(self.get("ok", 0))

    @property
    def failed_count(self) -> int:
        return int(self.get("failed", 0))

    def succeeded(self, ok_key: str = "ok") -> List[Dict[str, Any]]:
        """Items whose per-item success flag is set (``ok`` for job
        verbs, ``applied`` for content transitions)."""
        return [r for r in self.results if r.get(ok_key)]

    def failures(self, ok_key: str = "ok") -> List[Dict[str, Any]]:
        """Items that did not succeed; job-verb items carry their own
        409 ``error`` envelope, transition items the live status the
        rank guard kept."""
        return [r for r in self.results if not r.get(ok_key)]

    def raise_for_failures(self, ok_key: str = "ok") -> "BatchResult":
        """Strict mode: raise ConflictError if any item failed."""
        bad = self.failures(ok_key)
        if bad:
            raise ConflictError(
                f"{len(bad)}/{len(self.results)} batch items failed "
                f"(first: {bad[0]})")
        return self


class IDDSClient:
    def __init__(self, base_url: str, *, token: str = "",
                 timeout: float = 10.0, retries: int = 3,
                 backoff: float = 0.05):
        self.base_url = base_url.rstrip("/")
        self.token = token
        self.timeout = timeout
        self.retries = retries
        self.backoff = backoff

    # ------------------------------------------------------------- transport
    def _request(self, method: str, path: str,
                 body: Optional[bytes] = None, *,
                 idempotent: Optional[bool] = None,
                 raw: bool = False) -> Any:
        """One HTTP call with the retry policy.  ``idempotent=None``
        derives it from the verb (GET yes, POST no); non-idempotent
        calls are never retried — a 5xx or dropped connection leaves the
        server in an unknown state, and replaying could apply the action
        twice."""
        if idempotent is None:
            idempotent = method == "GET"
        url = self.base_url + path
        last_err: Optional[Exception] = None
        for attempt in range(self.retries + 1):
            req = urllib.request.Request(url, data=body, method=method)
            req.add_header("Content-Type", "application/json")
            if self.token:
                req.add_header("Authorization", f"Bearer {self.token}")
            try:
                with urllib.request.urlopen(req, timeout=self.timeout) as r:
                    text = r.read().decode("utf-8")
                    return text if raw else json.loads(text)
            except urllib.error.HTTPError as e:
                status = e.code
                try:
                    env = json.loads(e.read().decode("utf-8"))["error"]
                    etype, msg = env["type"], env["message"]
                except Exception:  # noqa: BLE001 — non-envelope body
                    etype, msg = "HTTPError", str(e)
                if status == 401:
                    raise AuthError(msg) from None
                if status == 404:
                    raise KeyError(msg) from None
                if status == 409:
                    raise ConflictError(msg) from None
                if status < 500:  # client errors never retry
                    raise IDDSClientError(status, etype, msg) from None
                last_err = IDDSClientError(status, etype, msg)
            except (urllib.error.URLError, ConnectionError, TimeoutError,
                    OSError) as e:
                last_err = e
            if not idempotent:
                # preserve the real HTTP status/type so callers can still
                # distinguish a 5xx from a dropped connection
                status, etype = ((last_err.status, last_err.type)
                                 if isinstance(last_err, IDDSClientError)
                                 else (0, type(last_err).__name__))
                raise IDDSClientError(
                    status, etype,
                    f"{method} {url} failed (non-idempotent call, not "
                    f"retried): {last_err}")
            if attempt < self.retries:
                # full jitter: desynchronizes a worker fleet hammering a
                # recovering head (0.5x..1.5x the exponential step)
                time.sleep(self.backoff * (2 ** attempt)
                           * (0.5 + random.random()))
        raise IDDSClientError(
            0, type(last_err).__name__,
            f"{method} {url} failed after {self.retries + 1} attempts: "
            f"{last_err}")

    def _get(self, path: str) -> Any:
        return self._request("GET", path)

    def _post(self, path: str, obj: Any, *,
              idempotent: bool = False) -> Any:
        return self._request("POST", path,
                             json.dumps(obj).encode("utf-8"),
                             idempotent=idempotent)

    # ------------------------------------------------------------ client API
    def submit(self, request_json: str) -> str:
        """Submit a serialized Request; returns the request_id.
        Retry-safe: the server deduplicates on the client-generated
        request_id."""
        return self._post(f"{API_PREFIX}/requests",
                          json.loads(request_json),
                          idempotent=True)["request_id"]

    def submit_workflow(self, wf: Workflow, requester: str = "anonymous",
                        token: Optional[str] = None) -> str:
        req = Request(workflow=wf, requester=requester,
                      token=self.token if token is None else token)
        return self.submit(req.to_json())

    def status(self, request_id: str) -> Dict[str, Any]:
        return self._get(
            f"{API_PREFIX}/requests/"
            f"{urllib.parse.quote(request_id)}")

    def list_requests(self, *, status: Optional[str] = None,
                      limit: Optional[int] = None,
                      offset: int = 0) -> Dict[str, Any]:
        """Catalog listing: ``{"requests": [...], "total": N, "limit":
        ..., "offset": ...}`` with optional status filter and
        limit/offset pagination (GET /requests)."""
        params = {}
        if status is not None:
            params["status"] = status
        if limit is not None:
            params["limit"] = str(limit)
        if offset:
            params["offset"] = str(offset)
        qs = urllib.parse.urlencode(params)
        return self._get(f"{API_PREFIX}/requests"
                         + (f"?{qs}" if qs else ""))

    def get_workflow(self, request_id: str) -> Workflow:
        d = self._get(
            f"{API_PREFIX}/requests/"
            f"{urllib.parse.quote(request_id)}/workflow")
        return Workflow.from_dict(d)

    def wait(self, request_id: str, timeout: float = 60.0,
             interval: float = 0.02) -> Dict[str, Any]:
        """Poll until the request reaches a terminal state (finished, or
        aborted by a command); returns the final status."""
        deadline = time.monotonic() + timeout
        while True:
            info = self.status(request_id)
            if info.get("status") in ("finished", "aborted"):
                return info
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"request {request_id} not finished in {timeout}s "
                    f"(last status: {info.get('status')})")
            time.sleep(interval)

    def list_transforms(self, request_id: str) -> Dict[str, Any]:
        """The request's Works as read resources (GET
        /v1/requests/<id>/transforms)."""
        return self._get(
            f"{API_PREFIX}/requests/"
            f"{urllib.parse.quote(request_id)}/transforms")

    def list_processings(self, request_id: str) -> Dict[str, Any]:
        """The request's Processings as read resources (GET
        /v1/requests/<id>/processings)."""
        return self._get(
            f"{API_PREFIX}/requests/"
            f"{urllib.parse.quote(request_id)}/processings")

    # ------------------------------------------- steering (lifecycle plane)
    def command(self, request_id: str, action: str, *,
                wait: bool = False,
                timeout: float = 30.0) -> Dict[str, Any]:
        """Submit a lifecycle command (abort/suspend/resume/retry).

        Retry-safe: a client-generated command_id makes the POST
        idempotent — a retried submission returns the journaled command
        instead of applying the action twice.  ``wait=True`` polls the
        command resource until the Commander has applied it.
        """
        cmd = self._post(
            f"{API_PREFIX}/requests/"
            f"{urllib.parse.quote(request_id)}/commands",
            {"action": action, "command_id": f"cmd-{uuid.uuid4().hex[:12]}"},
            idempotent=True)
        if wait:
            return self.wait_command(request_id, cmd["command_id"],
                                     timeout=timeout)
        return cmd

    def abort(self, request_id: str, **kw) -> Dict[str, Any]:
        """Abort the request: cancel its works/processings and revoke
        outstanding worker leases.  Terminal."""
        return self.command(request_id, "abort", **kw)

    def suspend(self, request_id: str, **kw) -> Dict[str, Any]:
        """Suspend the request: fence its jobs and park new dispatch."""
        return self.command(request_id, "suspend", **kw)

    def resume(self, request_id: str, **kw) -> Dict[str, Any]:
        """Resume a suspended request."""
        return self.command(request_id, "resume", **kw)

    def retry(self, request_id: str, **kw) -> Dict[str, Any]:
        """Re-run the request's terminally failed processings with a
        fresh attempt budget."""
        return self.command(request_id, "retry", **kw)

    def get_command(self, request_id: str,
                    command_id: str) -> Dict[str, Any]:
        return self._get(
            f"{API_PREFIX}/requests/{urllib.parse.quote(request_id)}"
            f"/commands/{urllib.parse.quote(command_id)}")

    def list_commands(self, request_id: str) -> Dict[str, Any]:
        return self._get(
            f"{API_PREFIX}/requests/"
            f"{urllib.parse.quote(request_id)}/commands")

    def wait_command(self, request_id: str, command_id: str,
                     timeout: float = 30.0,
                     interval: float = 0.02) -> Dict[str, Any]:
        """Poll a command until it leaves ``pending``."""
        deadline = time.monotonic() + timeout
        while True:
            cmd = self.get_command(request_id, command_id)
            if cmd["status"] != "pending":
                return cmd
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"command {command_id} still pending after {timeout}s")
            time.sleep(interval)

    def lookup_collection(self, name: str) -> Dict[str, Any]:
        return self._get(
            f"{API_PREFIX}/collections/"
            f"{urllib.parse.quote(name, safe='')}")

    def list_collections(self) -> Dict[str, Any]:
        """Collection catalog with per-collection content tallies (GET
        /v1/collections)."""
        return self._get(f"{API_PREFIX}/collections")

    def list_contents(self, name: str, *, status: Optional[str] = None,
                      limit: Optional[int] = None,
                      offset: int = 0) -> Dict[str, Any]:
        """Per-file content catalog: ``{"contents": [...], "total": N,
        "limit": ..., "offset": ...}`` with optional status filter
        (new/staging/available/delivered/failed) and pagination."""
        params = {}
        if status is not None:
            params["status"] = status
        if limit is not None:
            params["limit"] = str(limit)
        if offset:
            params["offset"] = str(offset)
        qs = urllib.parse.urlencode(params)
        return self._get(
            f"{API_PREFIX}/collections/"
            f"{urllib.parse.quote(name, safe='')}/contents"
            + (f"?{qs}" if qs else ""))

    def lookup_contents(self, name: str) -> List[Dict[str, Any]]:
        return self.list_contents(name)["contents"]

    # --------------------------------------------- delivery plane (consumer)
    def subscribe(self, consumer: str,
                  collections: Optional[List[str]] = None, *,
                  sub_id: Optional[str] = None,
                  push_url: Optional[str] = None) -> Dict[str, Any]:
        """Register a consumer subscription with the Conductor (POST
        /v1/subscriptions).  ``push_url`` switches it to webhook mode:
        the head's Publisher POSTs delivery batches there instead of
        waiting for this client to poll.  Retry-safe: a client-generated
        sub_id makes a replayed POST return the existing registration."""
        body: Dict[str, Any] = {
            "consumer": consumer,
            "sub_id": sub_id or f"sub-{uuid.uuid4().hex[:12]}",
        }
        if collections:
            body["collections"] = list(collections)
        if push_url is not None:
            body["push_url"] = push_url
        return self._post(f"{API_PREFIX}/subscriptions", body,
                          idempotent=True)

    def list_subscriptions(self, *, limit: Optional[int] = None,
                           offset: int = 0) -> Dict[str, Any]:
        """Subscription registry (GET /v1/subscriptions) with
        limit/offset pagination."""
        params = {}
        if limit is not None:
            params["limit"] = str(limit)
        if offset:
            params["offset"] = str(offset)
        qs = urllib.parse.urlencode(params)
        return self._get(f"{API_PREFIX}/subscriptions"
                         + (f"?{qs}" if qs else ""))

    def get_subscription(self, sub_id: str) -> Dict[str, Any]:
        return self._get(f"{API_PREFIX}/subscriptions/"
                         f"{urllib.parse.quote(sub_id)}")

    def _deliveries_qs(self, status: Optional[str],
                       limit: Optional[int], offset: int,
                       wait_s: Optional[float] = None) -> str:
        params = {}
        if status is not None:
            params["status"] = status
        if limit is not None:
            params["limit"] = str(limit)
        if offset:
            params["offset"] = str(offset)
        if wait_s:
            params["wait_s"] = str(wait_s)
        qs = urllib.parse.urlencode(params)
        return f"?{qs}" if qs else ""

    def list_deliveries(self, sub_id: str, *,
                        status: Optional[str] = None,
                        limit: Optional[int] = None,
                        offset: int = 0) -> Dict[str, Any]:
        """A subscription's tracked deliveries (GET
        /v1/subscriptions/<id>/deliveries), optionally filtered by
        status (notified/acked/failed) and paginated."""
        qs = self._deliveries_qs(status, limit, offset)
        return self._get(f"{API_PREFIX}/subscriptions/"
                         f"{urllib.parse.quote(sub_id)}/deliveries{qs}")

    def wait_deliveries(self, sub_id: str, *,
                        status: Optional[str] = None,
                        limit: Optional[int] = None,
                        offset: int = 0,
                        wait_s: float = 30.0) -> Dict[str, Any]:
        """Long-poll deliveries (GET .../deliveries?wait_s=): the server
        parks the request until a matching delivery lands or ``wait_s``
        expires, so a consumer sees a notification within milliseconds
        of fan-out without a tight poll loop.  The HTTP timeout is
        stretched to cover the park."""
        qs = self._deliveries_qs(status, limit, offset, wait_s)
        path = (f"{API_PREFIX}/subscriptions/"
                f"{urllib.parse.quote(sub_id)}/deliveries{qs}")
        url = self.base_url + path
        req = urllib.request.Request(url, method="GET")
        if self.token:
            req.add_header("Authorization", f"Bearer {self.token}")
        try:
            with urllib.request.urlopen(
                    req, timeout=self.timeout + wait_s) as r:
                return json.loads(r.read().decode("utf-8"))
        except urllib.error.HTTPError as e:
            self._raise_http(e)

    def events(self, sub_id: str, *,
               after_seq: Optional[int] = None,
               wait_s: float = 30.0):
        """Iterate one subscription's outbox events over SSE (GET
        /v1/subscriptions/<id>/events).  Yields each journaled outbox
        row as a dict; ``after_seq`` resumes past rows already seen
        (the server replays journaled rows missed while disconnected).
        The stream ends after ``wait_s`` server-side; re-call with the
        last row's ``seq`` to resume.  Heartbeat comment frames are
        filtered out."""
        params = {"wait_s": str(wait_s)}
        qs = urllib.parse.urlencode(params)
        path = (f"{API_PREFIX}/subscriptions/"
                f"{urllib.parse.quote(sub_id)}/events?{qs}")
        req = urllib.request.Request(self.base_url + path, method="GET")
        if self.token:
            req.add_header("Authorization", f"Bearer {self.token}")
        if after_seq is not None:
            req.add_header("Last-Event-ID", str(after_seq))
        try:
            resp = urllib.request.urlopen(req,
                                          timeout=self.timeout + wait_s)
        except urllib.error.HTTPError as e:
            self._raise_http(e)
        with resp:
            data_lines: List[str] = []
            for raw in resp:
                line = raw.decode("utf-8").rstrip("\n").rstrip("\r")
                if line.startswith(":"):
                    continue  # heartbeat comment
                if line.startswith("data:"):
                    data_lines.append(line[5:].lstrip())
                    continue
                if line == "" and data_lines:
                    yield json.loads("\n".join(data_lines))
                    data_lines = []

    def _raise_http(self, e: urllib.error.HTTPError):
        """Map an HTTPError to the SDK exception taxonomy (the
        streaming paths bypass ``_request``)."""
        try:
            env = json.loads(e.read().decode("utf-8"))["error"]
            etype, msg = env["type"], env["message"]
        except Exception:  # noqa: BLE001 — non-envelope body
            etype, msg = "HTTPError", str(e)
        if e.code == 401:
            raise AuthError(msg) from None
        if e.code == 404:
            raise KeyError(msg) from None
        if e.code == 409:
            raise ConflictError(msg) from None
        raise IDDSClientError(e.code, etype, msg) from None

    def ack(self, sub_id: str, delivery_ids: List[str]) -> Dict[str, Any]:
        """Acknowledge deliveries (POST /v1/subscriptions/<id>/ack).
        Retry-safe: acking is idempotent per delivery server-side."""
        return self._post(
            f"{API_PREFIX}/subscriptions/{urllib.parse.quote(sub_id)}/ack",
            {"delivery_ids": list(delivery_ids)}, idempotent=True)

    def stats(self) -> Dict[str, int]:
        return self._get(f"{API_PREFIX}/stats")

    def metrics(self, *, cluster: bool = False) -> str:
        """Prometheus text exposition (GET /v1/metrics); ``cluster=True``
        merges in the snapshots of every live peer head, each series
        tagged with a ``head`` label."""
        qs = "?cluster=1" if cluster else ""
        return self._request("GET", f"{API_PREFIX}/metrics{qs}", raw=True)

    def trace(self, request_id: str) -> Dict[str, Any]:
        """A request's reconstructed lifecycle timeline (GET
        /v1/requests/<id>/trace): journaled trace events plus paired
        spans with durations and per-head attribution."""
        return self._get(
            f"{API_PREFIX}/requests/"
            f"{urllib.parse.quote(request_id)}/trace")

    def healthz(self) -> Dict[str, Any]:
        return self._get(f"{API_PREFIX}/healthz")

    def cluster(self) -> Dict[str, Any]:
        """Head registry for the ownership plane (GET /v1/cluster):
        every head with a heartbeat in the shared store, its heartbeat
        age, liveness verdict and live workflow-claim count."""
        return self._get(f"{API_PREFIX}/cluster")

    # ----------------------------------------------- execution plane (jobs)
    def lease_job(self, worker_id: str, *,
                  queues: Optional[List[str]] = None,
                  ttl: Optional[float] = None,
                  manifest: Optional[List[str]] = None
                  ) -> Optional[Dict[str, Any]]:
        """Lease the next dispatchable job (POST /jobs/lease); None when
        nothing is pending.  Retry-safe: a fresh idempotency key per
        logical call means a retried request returns the same job rather
        than leasing a second one.  ``manifest`` reports the contents
        this worker already holds locally — an intel-enabled head routes
        jobs whose inputs match (cache-affinity scheduling)."""
        body: Dict[str, Any] = {
            "worker_id": worker_id,
            "idempotency_key": uuid.uuid4().hex,
        }
        if queues:
            body["queues"] = list(queues)
        if ttl is not None:
            body["lease_ttl"] = ttl
        if manifest is not None:
            body["manifest"] = list(manifest)
        return self._post(f"{API_PREFIX}/jobs/lease", body,
                          idempotent=True)["job"]

    def lease_jobs(self, worker_id: str, n: int, *,
                   queues: Optional[List[str]] = None,
                   ttl: Optional[float] = None,
                   manifest: Optional[List[str]] = None
                   ) -> List[Dict[str, Any]]:
        """Lease up to ``n`` jobs in one round trip and one scheduler
        lock grab (POST /jobs/lease?n=); returns a possibly-empty list.
        Retry-safe: the idempotency key replays the original grant."""
        body: Dict[str, Any] = {
            "worker_id": worker_id,
            "idempotency_key": uuid.uuid4().hex,
        }
        if queues:
            body["queues"] = list(queues)
        if ttl is not None:
            body["lease_ttl"] = ttl
        if manifest is not None:
            body["manifest"] = list(manifest)
        return self._post(f"{API_PREFIX}/jobs/lease?n={int(n)}", body,
                          idempotent=True)["jobs"]

    def heartbeat_jobs(self, job_ids: List[str], worker_id: str, *,
                       manifest: Optional[List[str]] = None
                       ) -> "BatchResult":
        """Renew many held leases in one round trip (POST
        /jobs/heartbeat).  Always 200; per-item envelopes in
        ``results`` carry status 200 or 409 — a stale lease shows up as
        its item's 409, never as an exception.  ``manifest`` refreshes
        the worker's cache-content report for affinity routing."""
        body: Dict[str, Any] = {"worker_id": worker_id,
                                "job_ids": list(job_ids)}
        if manifest is not None:
            body["manifest"] = list(manifest)
        return BatchResult(self._post(
            f"{API_PREFIX}/jobs/heartbeat", body, idempotent=True))

    def complete_jobs(self, items: List[Dict[str, Any]],
                      worker_id: str) -> "BatchResult":
        """Report many outcomes in one round trip (POST /jobs/complete).
        Each item is ``{"job_id", "result"?, "error"?}``; per-item
        envelopes as in :meth:`heartbeat_jobs`.  Retry-safe: the server
        deduplicates per (job, worker)."""
        return BatchResult(self._post(
            f"{API_PREFIX}/jobs/complete",
            {"worker_id": worker_id, "items": list(items)},
            idempotent=True))

    def transition_contents(self, name: str,
                            transitions: List[Dict[str, Any]]
                            ) -> "BatchResult":
        """Bulk content state changes (POST
        /collections/<name>/contents:transition).  Each transition is
        ``{"name", "status"}`` (+ optional ``size``); the response
        carries per-item ``applied`` flags.  Retry-safe: the rank guard
        makes replays no-ops."""
        return BatchResult(self._post(
            f"{API_PREFIX}/collections/"
            f"{urllib.parse.quote(name, safe='')}/contents:transition",
            {"transitions": list(transitions)}, idempotent=True))

    def heartbeat_job(self, job_id: str, worker_id: str, *,
                      manifest: Optional[List[str]] = None
                      ) -> Dict[str, Any]:
        """Renew a held lease; raises ConflictError once it is lost."""
        body: Dict[str, Any] = {"worker_id": worker_id}
        if manifest is not None:
            body["manifest"] = list(manifest)
        return self._post(
            f"{API_PREFIX}/jobs/{urllib.parse.quote(job_id)}/heartbeat",
            body, idempotent=True)

    def complete_job(self, job_id: str, worker_id: str, *,
                     result: Optional[Dict[str, Any]] = None,
                     error: Optional[str] = None) -> Dict[str, Any]:
        """Report a job outcome (result or error).  Retry-safe: the
        server deduplicates per (job, worker); a stale worker whose
        lease expired gets ConflictError and must drop the job."""
        return self._post(
            f"{API_PREFIX}/jobs/{urllib.parse.quote(job_id)}/complete",
            {"worker_id": worker_id, "result": result, "error": error},
            idempotent=True)

    def list_workers(self) -> Dict[str, Any]:
        """Execution-plane worker registry (GET /workers)."""
        return self._get(f"{API_PREFIX}/workers")

    def queues(self) -> Dict[str, Any]:
        """Per-queue scheduler state (GET /v1/queues): depth, suspended
        count, base and effective priority, learned completion rate."""
        return self._get(f"{API_PREFIX}/queues")

    def intel(self) -> Dict[str, Any]:
        """Intelligence-plane snapshot (GET /v1/intel): affinity
        hit-rate, per-queue history, hedge/rescore counters — or
        ``{"enabled": false}`` when the head runs with --intel off."""
        return self._get(f"{API_PREFIX}/intel")
