"""Directed-Graph workflow management (paper Fig. 3).

A ``Workflow`` is a set of ``WorkTemplate`` objects plus ``Condition``
branches.  Templates are *placeholders*: a concrete ``Work`` is generated
from a template by binding values to its pre-defined parameters.  When a
Work terminates, every Condition triggered by its template is evaluated;
each satisfied branch instantiates new Works from the follow-up templates
with freshly bound parameters (via a registered *binder*).  Because a
template may (transitively) re-trigger itself, the graph may contain
cycles — DG, not just DAG — bounded by ``max_iterations`` per condition.

One ``Work`` corresponds to one data transformation; it owns an input and
an output ``Collection`` whose contents the Transformer/Conductor daemons
track at *file* granularity (the carousel's incremental delivery).

Everything serializes to JSON (paper Fig. 2): callables are carried as
registry names (see payloads.py).
"""
from __future__ import annotations

import enum
import json
import time
import uuid
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core import payloads as reg


class WorkStatus(str, enum.Enum):
    NEW = "new"
    ACTIVATED = "activated"        # inputs being resolved (Transformer)
    TRANSFORMING = "transforming"  # processings created, not all done
    RUNNING = "running"
    FINISHED = "finished"
    SUBFINISHED = "subfinished"    # some processings failed terminally
    FAILED = "failed"
    CANCELLED = "cancelled"        # aborted by a lifecycle command

    @property
    def terminated(self) -> bool:
        return self in (WorkStatus.FINISHED, WorkStatus.SUBFINISHED,
                        WorkStatus.FAILED, WorkStatus.CANCELLED)


class ProcessingStatus(str, enum.Enum):
    NEW = "new"
    SUBMITTED = "submitted"
    RUNNING = "running"
    FINISHED = "finished"
    FAILED = "failed"
    CANCELLED = "cancelled"        # aborted by a lifecycle command


# ---------------------------------------------------------------------------
# Collections (DDM-facing data units)
# ---------------------------------------------------------------------------


# the content state machine (paper §2's Contents catalog): a file is
# registered `new`, becomes `staging` once the DDM starts moving it,
# `available` when it lands on disk, `delivered` once consumed (input:
# its processing finished; output: every subscribed consumer acked the
# notification), and `failed` when staging exhausts its attempts.
CONTENT_STATUSES = ("new", "staging", "available", "delivered", "failed")


@dataclass
class FileRef:
    """One file ('content') of a collection — the per-file Content
    record the delivery plane journals and exposes over REST."""
    name: str
    size: int = 0
    available: bool = False
    processed: bool = False
    status: str = ""
    created_at: float = 0.0
    updated_at: float = 0.0

    def __post_init__(self):
        if not self.status:
            self.status = "available" if self.available else "new"
        if not self.created_at:
            self.created_at = time.time()
        if not self.updated_at:
            self.updated_at = self.created_at

    def set_status(self, status: str) -> None:
        if status not in CONTENT_STATUSES:
            raise ValueError(f"invalid content status {status!r}")
        self.status = status
        self.updated_at = time.time()

    def to_dict(self):
        return asdict(self)

    @classmethod
    def from_dict(cls, d):
        d = {k: v for k, v in d.items() if v is not None}
        return cls(**d)


@dataclass
class Collection:
    name: str
    scope: str = "idds"
    files: List[FileRef] = field(default_factory=list)

    @property
    def n_available(self) -> int:
        return sum(f.available for f in self.files)

    @property
    def n_processed(self) -> int:
        return sum(f.processed for f in self.files)

    def status_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.files:
            out[f.status] = out.get(f.status, 0) + 1
        return out

    def to_dict(self):
        return {"name": self.name, "scope": self.scope,
                "files": [f.to_dict() for f in self.files]}

    @classmethod
    def from_dict(cls, d):
        c = cls(d["name"], d.get("scope", "idds"))
        c.files = [FileRef.from_dict(f) for f in d.get("files", [])]
        return c


# ---------------------------------------------------------------------------
# Work template / Work / Processing
# ---------------------------------------------------------------------------


@dataclass
class WorkTemplate:
    name: str
    payload: str                       # registry name
    defaults: Dict[str, Any] = field(default_factory=dict)
    input_collection: Optional[str] = None   # collection name pattern
    output_collection: Optional[str] = None
    # 'fine' -> one Processing per available file (incremental, the paper's
    # carousel mode); 'coarse' -> a single Processing once ALL files are
    # available (the pre-iDDS baseline).
    granularity: str = "fine"
    max_attempts: int = 3

    def to_dict(self):
        return asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


@dataclass
class Work:
    work_id: str
    template: str
    payload: str
    params: Dict[str, Any]
    status: WorkStatus = WorkStatus.NEW
    input_collection: Optional[str] = None
    output_collection: Optional[str] = None
    granularity: str = "fine"
    max_attempts: int = 3
    result: Optional[Dict[str, Any]] = None
    results: List[Dict[str, Any]] = field(default_factory=list)
    created_at: float = field(default_factory=time.time)
    terminated_at: Optional[float] = None
    iteration: int = 0          # DG cycle count at instantiation
    # True once the Marshaller has run this (terminated) Work through
    # condition evaluation.  Journaled atomically with the successors it
    # spawned, so recovery knows whether a terminal Work still owes a
    # T_WORK_DONE replay (crash between finalize and evaluation).
    condition_evaluated: bool = False

    def to_dict(self):
        d = asdict(self)
        d["status"] = self.status.value
        return d

    @classmethod
    def from_dict(cls, d):
        d = dict(d)
        d["status"] = WorkStatus(d["status"])
        return cls(**d)


@dataclass
class Processing:
    proc_id: str
    work_id: str
    payload: str
    params: Dict[str, Any]
    input_files: List[str] = field(default_factory=list)
    output_files: List[str] = field(default_factory=list)
    status: ProcessingStatus = ProcessingStatus.NEW
    attempt: int = 1
    max_attempts: int = 3
    result: Optional[Dict[str, Any]] = None
    error: Optional[str] = None

    @property
    def terminal(self) -> bool:
        """No further execution will happen: finished, cancelled by a
        lifecycle command, or failed with no attempts left.  A FAILED
        processing with attempts remaining is NOT terminal — the Carrier
        (or crash recovery) will retry it."""
        return (self.status in (ProcessingStatus.FINISHED,
                                ProcessingStatus.CANCELLED)
                or (self.status == ProcessingStatus.FAILED
                    and self.attempt >= self.max_attempts))

    def to_dict(self):
        d = asdict(self)
        d["status"] = self.status.value
        return d

    @classmethod
    def from_dict(cls, d):
        d = dict(d)
        d["status"] = ProcessingStatus(d["status"])
        return cls(**d)


# ---------------------------------------------------------------------------
# Conditions (DG edges)
# ---------------------------------------------------------------------------


@dataclass
class Branch:
    """One outgoing branch of a condition: instantiate ``template`` with
    params produced by ``binder(trigger_params, trigger_result)``."""
    template: str
    binder: str = "identity"

    def to_dict(self):
        return asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


@dataclass
class Condition:
    trigger: str                      # template name whose Works trigger this
    predicate: str = "always"
    true_next: List[Branch] = field(default_factory=list)
    false_next: List[Branch] = field(default_factory=list)
    max_iterations: int = 100         # cycle guard

    def to_dict(self):
        return {"trigger": self.trigger, "predicate": self.predicate,
                "true_next": [b.to_dict() for b in self.true_next],
                "false_next": [b.to_dict() for b in self.false_next],
                "max_iterations": self.max_iterations}

    @classmethod
    def from_dict(cls, d):
        return cls(
            trigger=d["trigger"], predicate=d.get("predicate", "always"),
            true_next=[Branch.from_dict(b) for b in d.get("true_next", [])],
            false_next=[Branch.from_dict(b)
                        for b in d.get("false_next", [])],
            max_iterations=d.get("max_iterations", 100))


# ---------------------------------------------------------------------------
# Workflow (the DG)
# ---------------------------------------------------------------------------


def _new_id(prefix: str) -> str:
    return f"{prefix}-{uuid.uuid4().hex[:12]}"


@dataclass
class Workflow:
    name: str
    templates: Dict[str, WorkTemplate] = field(default_factory=dict)
    conditions: List[Condition] = field(default_factory=list)
    initial: List[Tuple[str, Dict[str, Any]]] = field(default_factory=list)
    # --- runtime state (serialized too: a workflow is resumable) ---
    works: Dict[str, Work] = field(default_factory=dict)
    workflow_id: str = field(default_factory=lambda: _new_id("wf"))

    # -- construction helpers -------------------------------------------------
    def add_template(self, t: WorkTemplate) -> WorkTemplate:
        self.templates[t.name] = t
        return t

    def add_condition(self, c: Condition) -> Condition:
        if c.trigger not in self.templates:
            raise KeyError(f"condition trigger {c.trigger!r} not a template")
        for b in c.true_next + c.false_next:
            if b.template not in self.templates:
                raise KeyError(f"branch target {b.template!r} not a template")
        self.conditions.append(c)
        return c

    def add_initial(self, template: str, params: Optional[Dict] = None):
        if template not in self.templates:
            raise KeyError(f"initial template {template!r} unknown")
        self.initial.append((template, dict(params or {})))

    # -- instantiation --------------------------------------------------------
    def instantiate(self, template: str, params: Dict[str, Any],
                    iteration: int = 0) -> Work:
        t = self.templates[template]
        merged = {**t.defaults, **params}
        fmt = {**merged, "workflow": self.workflow_id}
        w = Work(
            work_id=_new_id("work"),
            template=t.name,
            payload=t.payload,
            params=merged,
            input_collection=(t.input_collection.format(**fmt)
                              if t.input_collection else None),
            output_collection=(t.output_collection.format(**fmt)
                               if t.output_collection else None),
            granularity=t.granularity,
            max_attempts=t.max_attempts,
            iteration=iteration,
        )
        self.works[w.work_id] = w
        return w

    def start(self) -> List[Work]:
        """Instantiate the initial Works (Clerk calls this)."""
        return [self.instantiate(t, p) for t, p in self.initial]

    # -- DG evaluation --------------------------------------------------------
    def on_terminated(self, work: Work) -> List[Work]:
        """Evaluate all conditions triggered by ``work``; instantiate and
        return the next generation of Works (paper Fig. 3 semantics).

        All-or-nothing: predicates and binders are all evaluated before
        any Work is instantiated, and a failure mid-instantiation rolls
        back — a raising predicate/binder must not leave orphan NEW Works
        in ``works`` that nobody will ever execute (they would pin the
        workflow at unfinished forever).
        """
        planned: List[Tuple[str, Dict[str, Any]]] = []
        for cond in self.conditions:
            if cond.trigger != work.template:
                continue
            if work.iteration + 1 > cond.max_iterations:
                continue  # cycle guard
            ok = reg.get_predicate(cond.predicate)(work, work.result)
            branches = cond.true_next if ok else cond.false_next
            for b in branches:
                bound = reg.get_binder(b.binder)(work.params, work.result)
                # a binder may fan out: list of param dicts -> one Work each
                for params in (bound if isinstance(bound, list) else [bound]):
                    planned.append((b.template, params))
        new_works: List[Work] = []
        try:
            for template, params in planned:
                new_works.append(
                    self.instantiate(template, params,
                                     iteration=work.iteration + 1))
        except Exception:
            for w in new_works:
                self.works.pop(w.work_id, None)
            raise
        return new_works

    @property
    def finished(self) -> bool:
        return (len(self.works) > 0 and
                all(w.status.terminated for w in self.works.values()))

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for w in self.works.values():
            out[w.status.value] = out.get(w.status.value, 0) + 1
        return out

    # -- JSON round trip (paper Fig. 2) ---------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "workflow_id": self.workflow_id,
            "templates": {k: t.to_dict() for k, t in self.templates.items()},
            "conditions": [c.to_dict() for c in self.conditions],
            "initial": [[t, p] for t, p in self.initial],
            "works": {k: w.to_dict() for k, w in self.works.items()},
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Workflow":
        wf = cls(name=d["name"], workflow_id=d.get("workflow_id",
                                                   _new_id("wf")))
        wf.templates = {k: WorkTemplate.from_dict(t)
                        for k, t in d.get("templates", {}).items()}
        wf.conditions = [Condition.from_dict(c)
                         for c in d.get("conditions", [])]
        wf.initial = [(t, dict(p)) for t, p in d.get("initial", [])]
        wf.works = {k: Work.from_dict(w)
                    for k, w in d.get("works", {}).items()}
        return wf

    @classmethod
    def from_json(cls, s: str) -> "Workflow":
        return cls.from_dict(json.loads(s))
