"""Payload / predicate / binder registries.

Workflows round-trip through JSON (Fig. 2), so they cannot carry Python
callables — they carry *names* resolved against these registries at
execution time, exactly as PanDA tasks carry transformation names.

  payload   (params, inputs) -> result dict           (the Work's compute)
  predicate (work, result) -> bool                    (Condition branches)
  binder    (params, result) -> new params            (template re-binding)
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict

_PAYLOADS: Dict[str, Callable[..., Any]] = {}
_PREDICATES: Dict[str, Callable[..., bool]] = {}
_BINDERS: Dict[str, Callable[..., Dict[str, Any]]] = {}


def _register(table: Dict[str, Any], kind: str, name: str, fn=None):
    def deco(f):
        table[name] = f  # last registration wins (supports re-loading)
        return f
    return deco(fn) if fn is not None else deco


def register_payload(name: str, fn=None):
    return _register(_PAYLOADS, "payload", name, fn)


def register_predicate(name: str, fn=None):
    return _register(_PREDICATES, "predicate", name, fn)


def register_binder(name: str, fn=None):
    return _register(_BINDERS, "binder", name, fn)


def get_payload(name: str) -> Callable[..., Any]:
    if name not in _PAYLOADS:
        raise KeyError(f"unknown payload {name!r}; known: {sorted(_PAYLOADS)}")
    return _PAYLOADS[name]


def get_predicate(name: str) -> Callable[..., bool]:
    if name not in _PREDICATES:
        raise KeyError(f"unknown predicate {name!r}")
    return _PREDICATES[name]


def get_binder(name: str) -> Callable[..., Dict[str, Any]]:
    if name not in _BINDERS:
        raise KeyError(f"unknown binder {name!r}")
    return _BINDERS[name]


# ---------------------------------------------------------------------------
# Built-ins used by tests/examples
# ---------------------------------------------------------------------------


register_payload("noop", lambda params, inputs: {"ok": True, **params})


@register_payload("sleep_ms")
def _sleep_ms(params, inputs):
    """Occupy a worker for ``ms`` milliseconds — the execution plane's
    stand-in for real compute (worker tests, worker_bench)."""
    ms = float(params.get("ms", 10))
    time.sleep(ms / 1000.0)
    return {"ok": True, "slept_ms": ms, "n_inputs": len(inputs)}


@register_predicate("always")
def _always(work, result) -> bool:
    return True


@register_predicate("result_true")
def _result_true(work, result) -> bool:
    return bool(result and result.get("decision", False))


@register_binder("identity")
def _identity(params, result):
    return dict(params)


@register_binder("increment_round")
def _increment_round(params, result):
    out = dict(params)
    out["round"] = int(out.get("round", 0)) + 1
    return out
