"""Delivery-plane entities: consumer subscriptions + tracked deliveries.

The paper's Conductor "delivers output data to consumers" — consumers
register interest in collections, and every per-file output availability
is matched against those registrations, notified on the bus, tracked,
retried while unacknowledged, and journaled through the Store so a head
crash loses no delivery state.

  * :class:`Subscription` — one consumer's registration: which
    collections (exact names or fnmatch patterns; empty = all) it wants
    output notifications for, plus the deliveries it has accrued.
  * :class:`Delivery` — one (subscription, content) notification record:
    ``notified`` -> ``acked`` (consumer confirmed receipt) or ``failed``
    (notification attempts exhausted).

The Conductor daemon (daemons.py) owns the state machine; the IDDS
facade (idds.py) exposes subscribe/list/ack, and rest.py mounts them at
``/v1/subscriptions``.
"""
from __future__ import annotations

import fnmatch
import random
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.core.workflow import _new_id

DELIVERY_STATUSES = ("notified", "acked", "failed")

# Outbox message statuses (store.py ``messages`` table): ``new`` rows
# await their first publish, ``queued`` rows are parked between retry
# attempts (``not_before`` backoff), ``delivered``/``failed`` are
# terminal.  The Publisher daemon drains the non-terminal set.
MESSAGE_STATUSES = ("new", "queued", "delivered", "failed")
UNDELIVERED_STATUSES = ("new", "queued")


def content_key(collection: str, file_name: str) -> str:
    return f"{collection}::{file_name}"


def backoff_delay(base: float, attempt: int, *, cap: float = 30.0,
                  rng: Optional[Callable[[], float]] = None) -> float:
    """Full-jitter exponential backoff: 0.5x..1.5x of the capped
    exponential step.  Shared by the Conductor's un-acked re-notify
    pass and the Publisher's webhook retries so neither can form a
    thundering re-notify herd at subscriber scale.  ``base`` 0 yields 0
    (tests collapse the schedule to immediate)."""
    step = min(cap, base * (2 ** max(attempt, 0)))
    r = rng() if rng is not None else random.random()
    return step * (0.5 + r)


def outbox_message(sub: "Subscription", d: "Delivery", *,
                   now: Optional[float] = None,
                   result: Optional[Dict[str, Any]] = None,
                   trace_id: Optional[str] = None) -> Dict[str, Any]:
    """One outbox row for one (subscription, delivery) notification.

    Journaled by the Conductor in the SAME store batch as the delivery
    transition that caused it (the transactional-outbox invariant), then
    published out-of-band by the Publisher daemon.  ``channel`` picks
    the fan-out path: ``webhook`` when the subscription registered a
    ``push_url``, ``bus`` otherwise (long-poll/SSE/legacy bus
    consumers)."""
    now = time.time() if now is None else now
    msg: Dict[str, Any] = {
        "msg_id": _new_id("msg"),
        "sub_id": sub.sub_id,
        "consumer": sub.consumer,
        "delivery_id": d.delivery_id,
        "collection": d.collection,
        "file": d.file,
        "delivery_attempt": d.attempts,
        "channel": "webhook" if sub.push_url else "bus",
        "status": "new",
        "attempts": 0,
        "not_before": None,
        "created_at": now,
        "updated_at": now,
    }
    if sub.push_url:  # freeze the endpoint at notify time
        msg["push_url"] = sub.push_url
    if result is not None:
        msg["result"] = result
    if trace_id is not None:
        msg["trace_id"] = trace_id
    return msg


@dataclass
class Delivery:
    """One tracked notification of one content to one subscriber."""
    delivery_id: str
    collection: str
    file: str
    status: str = "notified"
    attempts: int = 1            # notifications published so far
    created_at: float = field(default_factory=time.time)
    updated_at: float = 0.0

    def __post_init__(self):
        if not self.updated_at:
            self.updated_at = self.created_at

    def set_status(self, status: str) -> None:
        if status not in DELIVERY_STATUSES:
            raise ValueError(f"invalid delivery status {status!r}")
        self.status = status
        self.updated_at = time.time()

    def to_dict(self) -> Dict[str, Any]:
        return {"delivery_id": self.delivery_id,
                "collection": self.collection, "file": self.file,
                "status": self.status, "attempts": self.attempts,
                "created_at": self.created_at,
                "updated_at": self.updated_at}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Delivery":
        return cls(**d)


@dataclass
class Subscription:
    """One consumer's registration with the delivery plane."""
    sub_id: str = field(default_factory=lambda: _new_id("sub"))
    consumer: str = "anonymous"
    # collection names or fnmatch patterns; empty list = every collection
    collections: List[str] = field(default_factory=list)
    created_at: float = field(default_factory=time.time)
    # keyed by content_key(collection, file): at most one delivery per
    # content per subscription, however often the output is re-announced
    deliveries: Dict[str, Delivery] = field(default_factory=dict)
    # webhook mode: the Publisher POSTs delivery batches here instead of
    # waiting for the consumer to poll/long-poll (None = pull channels)
    push_url: Optional[str] = None

    def matches(self, collection: Optional[str]) -> bool:
        if not collection:
            return False
        if not self.collections:
            return True
        return any(fnmatch.fnmatchcase(collection, pat)
                   for pat in self.collections)

    def ensure_delivery(self, collection: str,
                        file_name: str) -> Optional[Delivery]:
        """Create the delivery for this content, or None if it already
        exists (duplicate output announcement)."""
        key = content_key(collection, file_name)
        if key in self.deliveries:
            return None
        d = Delivery(delivery_id=_new_id("dlv"), collection=collection,
                     file=file_name)
        self.deliveries[key] = d
        return d

    def find_delivery(self, delivery_id: str) -> Optional[Delivery]:
        # deliveries are keyed by content (for ensure_delivery dedup)
        # but the public API addresses delivery_id: keep a lazy id
        # index so batch acks are O(k), not O(k * deliveries).  The
        # dict only ever grows, so a size check detects staleness.
        idx = self.__dict__.get("_by_id")
        if idx is None or len(idx) != len(self.deliveries):
            idx = {d.delivery_id: d for d in self.deliveries.values()}
            self.__dict__["_by_id"] = idx
        return idx.get(delivery_id)

    def counts(self) -> Dict[str, int]:
        out = {s: 0 for s in DELIVERY_STATUSES}
        for d in self.deliveries.values():
            out[d.status] = out.get(d.status, 0) + 1
        return out

    def to_dict(self) -> Dict[str, Any]:
        return {"sub_id": self.sub_id, "consumer": self.consumer,
                "collections": list(self.collections),
                "created_at": self.created_at,
                "push_url": self.push_url,
                "deliveries": {k: d.to_dict()
                               for k, d in self.deliveries.items()}}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Subscription":
        return cls(
            sub_id=d["sub_id"], consumer=d.get("consumer", "anonymous"),
            collections=list(d.get("collections", [])),
            created_at=d.get("created_at", 0.0) or time.time(),
            push_url=d.get("push_url"),
            deliveries={k: Delivery.from_dict(v)
                        for k, v in d.get("deliveries", {}).items()})

    def summary(self) -> Dict[str, Any]:
        """The REST-facing view: registration + delivery tallies (the
        full delivery list has its own resource)."""
        return {"sub_id": self.sub_id, "consumer": self.consumer,
                "collections": list(self.collections),
                "created_at": self.created_at,
                "push_url": self.push_url,
                "deliveries": self.counts()}
