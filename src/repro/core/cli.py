"""Operator CLI for the iDDS REST gateway (the steering console).

Thin argparse front-end over :class:`repro.core.client.IDDSClient` —
every verb maps to one SDK call against the ``/v1`` namespace and
prints the JSON response, so output composes with ``jq`` and scripts.

    PYTHONPATH=src python -m repro.core.cli --url http://127.0.0.1:8443 \
        [--token T] VERB [ARGS]

Verbs:

  health                      GET /v1/healthz (head identity, queue
                              depths, pending commands, daemon
                              liveness, content + delivery tallies)
  cluster                     GET /v1/cluster (head registry:
                              heartbeat ages, live claim counts)
  stats                       GET /v1/stats
  list [--status S] [--limit N] [--offset N]
  status REQUEST_ID           status + work counts + suspended flag
  workflow REQUEST_ID         the full DG state
  transforms REQUEST_ID       the request's Works
  processings REQUEST_ID      the request's Processings
  commands REQUEST_ID         the request's command journal
  submit FILE [--requester R] submit a workflow JSON file (a Workflow
                              dict, e.g. WorkflowSpec(...).build()
                              .to_dict()); '-' reads stdin
  abort REQUEST_ID            \\
  suspend REQUEST_ID           } lifecycle commands; --no-wait returns
  resume REQUEST_ID           /  immediately instead of polling until
  retry REQUEST_ID           /   the Commander applied the command
  workers                     execution-plane worker registry
  queues                      per-queue scheduler state (depth,
                              suspended count, base + effective
                              priority, learned completion rate)
  intel                       intelligence-plane snapshot (affinity
                              hit-rate, learned per-queue history,
                              hedge/rescore counters)
  collections                 collection catalog + content tallies
  contents NAME [--status S] [--limit N] [--offset N]
                              per-file content records of a collection
  subscribe --consumer C [--collections A,B] [--push-url URL]
                              register with the delivery plane;
                              --push-url switches to webhook fan-out
  subscriptions [--limit N] [--offset N]
                              subscription registry
  deliveries SUB_ID [--status S] [--limit N] [--offset N] [--wait S]
                              a subscription's tracked deliveries;
                              --wait long-polls until one lands
  events SUB_ID [--after SEQ] [--wait S]
                              stream the subscription's outbox events
                              over SSE, one JSON object per line;
                              --after resumes past a seq cursor
  ack SUB_ID DELIVERY_ID...   acknowledge deliveries
  metrics [--cluster]         GET /v1/metrics — Prometheus text
                              exposition (raw, not JSON); --cluster
                              merges every live head's series
  trace REQUEST_ID            GET /v1/requests/<id>/trace — the
                              request's lifecycle span timeline
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.client import IDDSClient
from repro.core.requests import Request
from repro.core.workflow import Workflow

COMMAND_VERBS = ("abort", "suspend", "resume", "retry")


def _print(obj) -> None:
    print(json.dumps(obj, indent=2, sort_keys=True))


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.cli",
        description="Steer and inspect an iDDS head service over HTTP.")
    ap.add_argument("--url", default="http://127.0.0.1:8443")
    ap.add_argument("--token", default="")
    ap.add_argument("--timeout", type=float, default=10.0)
    sub = ap.add_subparsers(dest="verb", required=True)

    sub.add_parser("health")
    sub.add_parser("cluster")
    sub.add_parser("stats")
    sub.add_parser("workers")
    sub.add_parser("queues")
    sub.add_parser("intel")

    p = sub.add_parser("list")
    p.add_argument("--status", default=None)
    p.add_argument("--limit", type=int, default=None)
    p.add_argument("--offset", type=int, default=0)

    for verb in ("status", "workflow", "transforms", "processings",
                 "commands"):
        p = sub.add_parser(verb)
        p.add_argument("request_id")

    p = sub.add_parser("submit")
    p.add_argument("file", help="workflow JSON file ('-' for stdin)")
    p.add_argument("--requester", default="cli")
    p.add_argument("--wait", action="store_true",
                   help="poll until the request finishes")

    for verb in COMMAND_VERBS:
        p = sub.add_parser(verb)
        p.add_argument("request_id")
        p.add_argument("--no-wait", action="store_true",
                       help="return the pending command immediately "
                            "instead of polling until it applied")

    sub.add_parser("collections")

    p = sub.add_parser("subscriptions")
    p.add_argument("--limit", type=int, default=None)
    p.add_argument("--offset", type=int, default=0)

    p = sub.add_parser("contents")
    p.add_argument("name")
    p.add_argument("--status", default=None)
    p.add_argument("--limit", type=int, default=None)
    p.add_argument("--offset", type=int, default=0)

    p = sub.add_parser("subscribe")
    p.add_argument("--consumer", required=True)
    p.add_argument("--collections", default=None,
                   help="comma-separated collection names or fnmatch "
                        "patterns (omit = every collection)")
    p.add_argument("--push-url", default=None,
                   help="webhook mode: the head POSTs delivery batches "
                        "to this http(s) URL instead of waiting for "
                        "polls")

    p = sub.add_parser("deliveries")
    p.add_argument("sub_id")
    p.add_argument("--status", default=None)
    p.add_argument("--limit", type=int, default=None)
    p.add_argument("--offset", type=int, default=0)
    p.add_argument("--wait", type=float, default=None, metavar="S",
                   help="long-poll: park up to S seconds until a "
                        "delivery lands instead of returning an empty "
                        "listing")

    p = sub.add_parser("events")
    p.add_argument("sub_id")
    p.add_argument("--after", type=int, default=None, metavar="SEQ",
                   help="resume cursor: replay journaled events with "
                        "seq greater than this")
    p.add_argument("--wait", type=float, default=30.0, metavar="S",
                   help="how long the SSE stream stays open server-side")

    p = sub.add_parser("ack")
    p.add_argument("sub_id")
    p.add_argument("delivery_ids", nargs="+")

    p = sub.add_parser("metrics")
    p.add_argument("--cluster", action="store_true",
                   help="aggregate every live head's series (each "
                        "tagged with a 'head' label) instead of just "
                        "the answering head's")

    p = sub.add_parser("trace")
    p.add_argument("request_id")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    client = IDDSClient(args.url, token=args.token, timeout=args.timeout)
    try:
        if args.verb == "health":
            _print(client.healthz())
        elif args.verb == "cluster":
            _print(client.cluster())
        elif args.verb == "stats":
            _print(client.stats())
        elif args.verb == "workers":
            _print(client.list_workers())
        elif args.verb == "queues":
            _print(client.queues())
        elif args.verb == "intel":
            _print(client.intel())
        elif args.verb == "list":
            _print(client.list_requests(status=args.status,
                                        limit=args.limit,
                                        offset=args.offset))
        elif args.verb == "status":
            _print(client.status(args.request_id))
        elif args.verb == "workflow":
            _print(client.get_workflow(args.request_id).to_dict())
        elif args.verb == "transforms":
            _print(client.list_transforms(args.request_id))
        elif args.verb == "processings":
            _print(client.list_processings(args.request_id))
        elif args.verb == "commands":
            _print(client.list_commands(args.request_id))
        elif args.verb == "submit":
            raw = (sys.stdin.read() if args.file == "-"
                   else open(args.file).read())
            wf = Workflow.from_dict(json.loads(raw))
            req = Request(workflow=wf, requester=args.requester,
                          token=client.token)
            rid = client.submit(req.to_json())
            if args.wait:
                _print(client.wait(rid))
            else:
                _print({"request_id": rid, "status": "accepted"})
        elif args.verb in COMMAND_VERBS:
            _print(client.command(args.request_id, args.verb,
                                  wait=not args.no_wait))
        elif args.verb == "collections":
            _print(client.list_collections())
        elif args.verb == "contents":
            _print(client.list_contents(args.name, status=args.status,
                                        limit=args.limit,
                                        offset=args.offset))
        elif args.verb == "subscribe":
            colls = ([c for c in args.collections.split(",") if c]
                     if args.collections else None)
            _print(client.subscribe(args.consumer, colls,
                                    push_url=args.push_url))
        elif args.verb == "subscriptions":
            _print(client.list_subscriptions(limit=args.limit,
                                             offset=args.offset))
        elif args.verb == "deliveries":
            if args.wait:
                _print(client.wait_deliveries(args.sub_id,
                                              status=args.status,
                                              limit=args.limit,
                                              offset=args.offset,
                                              wait_s=args.wait))
            else:
                _print(client.list_deliveries(args.sub_id,
                                              status=args.status,
                                              limit=args.limit,
                                              offset=args.offset))
        elif args.verb == "events":
            # one JSON object per line as they stream in (jq-friendly)
            for ev in client.events(args.sub_id, after_seq=args.after,
                                    wait_s=args.wait):
                print(json.dumps(ev), flush=True)
        elif args.verb == "ack":
            _print(client.ack(args.sub_id, args.delivery_ids))
        elif args.verb == "metrics":
            # Prometheus exposition is already text — print verbatim
            sys.stdout.write(client.metrics(cluster=args.cluster))
        elif args.verb == "trace":
            _print(client.trace(args.request_id))
    except KeyError as e:
        print(json.dumps({"error": {"type": "NotFound",
                                    "message": str(e)}}), file=sys.stderr)
        return 2
    except Exception as e:  # noqa: BLE001 — CLI boundary
        print(json.dumps({"error": {"type": type(e).__name__,
                                    "message": str(e)}}), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
