"""REST gateway for the iDDS head service (paper §2).

The paper describes iDDS as "a general Restful service to receive
requests from WFMS" — this module is that network boundary.  It wraps an
in-process :class:`repro.core.idds.IDDS` in a thread-pooled stdlib HTTP
server so workflows can be submitted, steered and tracked over the wire
by any client speaking JSON (see :mod:`repro.core.client` for the typed
SDK and :mod:`repro.core.cli` for the operator CLI).

The public surface is the **versioned /v1 namespace** (all JSON;
details + deprecation table in docs/rest_api.md):

  POST /v1/requests                        submit a serialized Request
  GET  /v1/requests                        catalog listing (status
                                           filter, limit/offset)
  GET  /v1/requests/<id>                   status + work counts +
                                           suspended flag
  GET  /v1/requests/<id>/workflow          full workflow state (the DG)
  GET  /v1/requests/<id>/transforms        the request's Works
  GET  /v1/requests/<id>/processings       the request's Processings
  POST /v1/requests/<id>/commands          steer: abort / suspend /
                                           resume / retry (202)
  GET  /v1/requests/<id>/commands          command journal
  GET  /v1/requests/<id>/commands/<cid>    one command's state
  GET  /v1/collections                     collection catalog + tallies
  GET  /v1/collections/<name>              collection metadata
  GET  /v1/collections/<name>/contents     per-file content records
                                           (status filter, limit/offset)
  POST /v1/subscriptions                   register a consumer with the
                                           delivery plane (201); a
                                           push_url switches it to
                                           webhook fan-out
  GET  /v1/subscriptions                   subscription registry
                                           (limit/offset)
  GET  /v1/subscriptions/<id>              one subscription + tallies
  GET  /v1/subscriptions/<id>/deliveries   tracked deliveries (status
                                           filter, limit/offset);
                                           ?wait_s= long-polls until a
                                           delivery lands
  GET  /v1/subscriptions/<id>/events       Server-Sent Events stream of
                                           journaled outbox rows;
                                           Last-Event-ID (or ?after=)
                                           resumes from the seq cursor
  POST /v1/subscriptions/<id>/ack          acknowledge deliveries
  POST /v1/collections/<name>/contents:transition
                                           bulk content state changes
                                           (per-item applied flags)
  POST /v1/jobs/lease                      worker: lease the next job;
                                           ?n= leases up to n jobs in
                                           one scheduler lock grab
  POST /v1/jobs/heartbeat                  worker: renew many leases
                                           (per-item envelopes)
  POST /v1/jobs/complete                   worker: report many outcomes
                                           (per-item envelopes)
  POST /v1/jobs/<id>/heartbeat             worker: renew a held lease
  POST /v1/jobs/<id>/complete              worker: report result/error
  GET  /v1/workers                         worker registry
  GET  /v1/queues                          per-queue scheduler state
                                           (depth, suspended, priority,
                                           completion rate)
  GET  /v1/intel                           intelligence-plane snapshot
                                           (affinity hit-rate, learned
                                           history, hedge counters);
                                           {"enabled": false} on
                                           --intel off heads
  GET  /v1/stats                           daemon counters
  GET  /v1/cluster                         head registry: heartbeat
                                           ages, live-claim counts
  GET  /v1/healthz                         liveness + head identity +
                                           bus backend + store backend +
                                           scheduler queue depths +
                                           pending-command count
                                           (never requires auth)

Legacy (pre-v1, unversioned) paths are governed by ``legacy_routes``:
in ``"warn"`` mode (default) they answer normally plus a
``Deprecation: true`` response header and a
``Link: </v1/...>; rel="successor-version"`` pointer; in ``"off"``
mode they return **410 Gone** with a JSON envelope whose
``error.successor`` names the /v1 replacement.  ``/healthz`` is exempt
(liveness probes predate versioning and must keep answering).  The
v1-only resources (transforms/processings/commands/cluster) have no
unversioned alias in either mode.

The /jobs endpoints are the pull-based execution plane (paper's pilot
model): they 400 with type ``NotDistributed`` unless the head runs a
``DistributedWFM`` executor, and lease-validation failures (expired or
reassigned leases) are 409 envelopes with type ``Conflict``.  Lifecycle
conflicts (e.g. resuming a request that is not suspended) are 409
envelopes too.  A known path hit with the wrong method is a 405
envelope carrying an ``Allow`` header listing the methods that work.

Auth: a bearer token (``Authorization: Bearer <t>`` or ``X-IDDS-Token``)
checked against the IDDS token set; failures surface as the same
``AuthError`` the in-process facade raises and map to HTTP 401.  Every
error is a JSON envelope ``{"error": {"type": ..., "message": ...}}``.

Run standalone:

    PYTHONPATH=src python -m repro.core.rest --port 8443 \
        --tokens s3cret --payloads my_payload_module
"""
from __future__ import annotations

import argparse
import importlib
import json
import re
import signal
import threading
import time
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.commands import CommandConflict
from repro.core.idds import IDDS, AuthError
from repro.core.obs import setup_logging
from repro.core.scheduler import DistributedWFM, SchedulerConflict
from repro.core.store import BufferedStore, SqliteStore

MAX_BODY_BYTES = 16 * 1024 * 1024  # refuse absurd submissions
MAX_LEASE_BATCH = 64     # ?n= upper bound on POST /jobs/lease
MAX_BATCH_ITEMS = 256    # job_ids/items upper bound on batch verbs
MAX_MANIFEST_ITEMS = 1024  # worker cache-manifest entries kept per report
MAX_TRANSITION_ITEMS = 4096  # transitions upper bound (stager sweeps)
MAX_WAIT_S = 60.0        # ?wait_s= long-poll park upper bound
MAX_STREAM_S = 300.0     # SSE stream duration upper bound per request
SSE_HEARTBEAT_S = 10.0   # idle SSE comment-frame cadence


class RestGateway:
    """HTTP front-end owning the lifecycle of an IDDS head service.

    ``start()`` spins the IDDS daemon threads and then the HTTP server;
    ``stop()`` tears both down in reverse order.  Also usable as a
    context manager.  ``port=0`` binds an ephemeral port (tests).
    """

    def __init__(self, idds: Optional[IDDS] = None, *,
                 host: str = "127.0.0.1", port: int = 0,
                 tokens: Optional[Set[str]] = None,
                 manage_idds: bool = True, quiet: bool = True,
                 legacy_routes: str = "warn"):
        self.idds = idds if idds is not None else IDDS(tokens=tokens)
        if tokens is not None and idds is not None:
            self.idds._tokens = set(tokens)
        if legacy_routes not in ("warn", "off"):
            raise ValueError("legacy_routes must be 'warn' or 'off'")
        self.legacy_routes = legacy_routes
        self.host = host
        self._requested_port = port
        self.manage_idds = manage_idds
        self.quiet = quiet
        self.started_at: Optional[float] = None
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        # healthz content/delivery tallies are O(catalog) to compute;
        # cache them briefly so a tight monitoring loop cannot turn the
        # liveness probe into a head-service load source
        self._tally_ttl = 1.0
        self._tally_cache: Tuple[float, Optional[Dict], Optional[Dict]] \
            = (0.0, None, None)
        # per-route telemetry families (children resolved per request)
        reg = self.idds.metrics
        self._obs_req_hist = reg.histogram(
            "rest_request_seconds", "per-route request latency",
            labels=("route",))
        self._obs_req_count = reg.counter(
            "rest_requests_total", "requests served, by route and status",
            labels=("route", "status"))

    # ------------------------------------------------------------ lifecycle
    @property
    def port(self) -> int:
        if self._httpd is None:
            return self._requested_port
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "RestGateway":
        if self._httpd is not None:
            raise RuntimeError("gateway already started")
        if self.manage_idds:
            self.idds.start()
        handler = _make_handler(self)
        server_cls = type("IDDSHTTPServer", (ThreadingHTTPServer,), {
            # urllib clients open a fresh connection per call: the default
            # listen backlog of 5 drops SYNs under concurrent load (1s
            # retransmit stalls in benchmarks)
            "request_queue_size": 128,
        })
        self._httpd = server_cls((self.host, self._requested_port), handler)
        self._httpd.daemon_threads = True
        # small JSON responses: Nagle + delayed ACK costs ~40ms per poll
        self._httpd.disable_nagle_algorithm = True
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="idds-rest", daemon=True)
        self._thread.start()
        self.started_at = time.time()
        return self

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        if self.manage_idds:
            self.idds.stop()

    def __enter__(self) -> "RestGateway":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ handlers
    # Each returns (http_status, json-serializable body).
    def handle_submit(self, body: bytes, token: str) -> Tuple[int, Dict]:
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        if "workflow" not in d:
            return 400, _err("BadRequest",
                             "body must be a Request object with a "
                             "'workflow' field")
        if token and not d.get("token"):
            d["token"] = token  # header auth wins over an empty body token
        try:
            request_id = self.idds.submit(json.dumps(d))
        except AuthError as e:
            return 401, _err("AuthError", str(e))
        except (KeyError, TypeError, ValueError) as e:
            return 400, _err("BadRequest", f"malformed request: {e}")
        return 201, {"request_id": request_id, "status": "accepted"}

    def handle_list(self, query: Dict[str, List[str]],
                    token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        status = query.get("status", [None])[0]
        limit, offset, err = _parse_page(query)
        if err is not None:
            return err
        try:
            return 200, self.idds.list_requests(status=status, limit=limit,
                                                offset=offset)
        except ValueError as e:
            return 400, _err("BadRequest", str(e))

    def handle_status(self, request_id: str, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        try:
            return 200, self.idds.request_status(request_id)
        except KeyError:
            return 404, _err("NotFound", f"unknown request {request_id!r}")

    def handle_workflow(self, request_id: str, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        try:
            return 200, self.idds.workflow_dict(request_id)
        except KeyError:
            return 404, _err("NotFound", f"unknown request {request_id!r}")

    def handle_transforms(self, request_id: str,
                          token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        try:
            return 200, self.idds.list_transforms(request_id)
        except KeyError:
            return 404, _err("NotFound", f"unknown request {request_id!r}")

    def handle_processings(self, request_id: str,
                           token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        try:
            return 200, self.idds.list_processings(request_id)
        except KeyError:
            return 404, _err("NotFound", f"unknown request {request_id!r}")

    # -- steering (request lifecycle commands) ---------------------------
    def handle_command_submit(self, request_id: str, body: bytes,
                              token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        action = d.get("action")
        if not action or not isinstance(action, str):
            return 400, _err("BadRequest", "action (string) is required")
        command_id = d.get("command_id")
        if command_id is not None and not isinstance(command_id, str):
            return 400, _err("BadRequest", "command_id must be a string")
        try:
            cmd = self.idds.command(request_id, action,
                                    command_id=command_id)
        except KeyError:
            return 404, _err("NotFound", f"unknown request {request_id!r}")
        except ValueError as e:
            return 400, _err("BadRequest", str(e))
        except CommandConflict as e:
            return 409, _err("Conflict", str(e))
        # 202: the Commander applies asynchronously; poll the command URL
        return 202, cmd

    def handle_command_list(self, request_id: str,
                            token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        try:
            return 200, self.idds.list_commands(request_id)
        except KeyError:
            return 404, _err("NotFound", f"unknown request {request_id!r}")

    def handle_command_get(self, request_id: str, command_id: str,
                           token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        try:
            return 200, self.idds.get_command(request_id, command_id)
        except KeyError:
            return 404, _err("NotFound",
                             f"unknown command {command_id!r}")

    def handle_collection(self, name: str, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        try:
            return 200, self.idds.lookup_collection(name)
        except KeyError:
            return 404, _err("NotFound", f"unknown collection {name!r}")

    def handle_collections(self, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        return 200, self.idds.list_collections()

    def handle_contents(self, name: str, query: Dict[str, List[str]],
                        token: str) -> Tuple[int, Any]:
        self.idds._auth(token)
        status = query.get("status", [None])[0]
        limit, offset, err = _parse_page(query)
        if err is not None:
            return err
        try:
            return 200, self.idds.list_contents(name, status=status,
                                                limit=limit, offset=offset)
        except ValueError as e:
            return 400, _err("BadRequest", str(e))
        except KeyError:
            return 404, _err("NotFound", f"unknown collection {name!r}")

    def handle_contents_transition(self, name: str, body: bytes,
                                   token: str) -> Tuple[int, Dict]:
        """Bulk content state changes (Stager/Conductor sweeps): one
        journal commit for the whole batch, per-item ``applied`` flags
        (a rank-guard rejection is not an error — the row just already
        moved further along)."""
        self.idds._auth(token)
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        transitions = d.get("transitions")
        if not isinstance(transitions, list) or not transitions:
            return 400, _err("BadRequest",
                             "transitions (non-empty list) is required")
        if len(transitions) > MAX_TRANSITION_ITEMS:
            return 400, _err(
                "BadRequest",
                f"at most {MAX_TRANSITION_ITEMS} transitions per batch")
        try:
            out = self.idds.transition_contents(name, transitions)
        except ValueError as e:
            return 400, _err("BadRequest", str(e))
        except KeyError:
            return 404, _err("NotFound", f"unknown collection {name!r}")
        return 200, batch_envelope(out["results"], ok_key="applied",
                                   collection=out["collection"],
                                   applied=out["applied"],
                                   skipped=out["skipped"])

    # -- delivery plane (consumer subscriptions) --------------------------
    def handle_subscribe(self, body: bytes, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        consumer = d.get("consumer")
        if not consumer or not isinstance(consumer, str):
            return 400, _err("BadRequest", "consumer (string) is required")
        collections = d.get("collections")
        if collections is not None and (
                not isinstance(collections, list)
                or not all(isinstance(c, str) and c for c in collections)):
            return 400, _err("BadRequest",
                             "collections must be a string list")
        sub_id = d.get("sub_id")
        if sub_id is not None and not isinstance(sub_id, str):
            return 400, _err("BadRequest", "sub_id must be a string")
        push_url = d.get("push_url")
        if push_url is not None and not isinstance(push_url, str):
            return 400, _err("BadRequest", "push_url must be a string")
        try:
            sub = self.idds.subscribe(consumer, collections,
                                      sub_id=sub_id, push_url=push_url)
        except ValueError as e:
            return 400, _err("BadRequest", str(e))
        return 201, sub

    def handle_subscriptions(self, query: Dict[str, List[str]],
                             token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        limit, offset, err = _parse_page(query)
        if err is not None:
            return err
        try:
            return 200, self.idds.list_subscriptions(limit=limit,
                                                     offset=offset)
        except ValueError as e:
            return 400, _err("BadRequest", str(e))

    def handle_subscription(self, sub_id: str,
                            token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        try:
            return 200, self.idds.get_subscription(sub_id)
        except KeyError:
            return 404, _err("NotFound",
                             f"unknown subscription {sub_id!r}")

    def handle_deliveries(self, sub_id: str, query: Dict[str, List[str]],
                          token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        status = query.get("status", [None])[0]
        limit, offset, err = _parse_page(query)
        if err is not None:
            return err
        wait_raw = query.get("wait_s", [None])[0]
        wait_s = 0.0
        if wait_raw is not None:
            try:
                wait_s = float(wait_raw)
            except (TypeError, ValueError):
                return 400, _err("BadRequest", "wait_s must be a number")
            if wait_s < 0:
                return 400, _err("BadRequest",
                                 "wait_s must be non-negative")
            # cap: a parked handler holds one server thread
            wait_s = min(wait_s, MAX_WAIT_S)
        try:
            return 200, self.idds.wait_deliveries(
                sub_id, status=status, limit=limit, offset=offset,
                wait_s=wait_s)
        except ValueError as e:
            return 400, _err("BadRequest", str(e))
        except KeyError:
            return 404, _err("NotFound",
                             f"unknown subscription {sub_id!r}")

    def handle_events(self, sub_id: str, query: Dict[str, List[str]],
                      token: str,
                      last_event_id: Optional[str] = None
                      ) -> Tuple[int, Any]:
        """Server-Sent Events stream of one subscription's journaled
        outbox rows.  Each frame is ``id: <seq>`` + ``event: delivery``
        + the row as JSON ``data:``; the ``Last-Event-ID`` request
        header (what EventSource sends on reconnect) or ``?after=``
        resumes past rows already seen — journaled rows missed while
        disconnected are replayed, so resume loses nothing.  The stream
        closes itself after ``?wait_s=`` (capped) seconds; idle periods
        carry comment heartbeats so proxies don't reap the socket."""
        self.idds._auth(token)
        after_raw = (last_event_id if last_event_id
                     else query.get("after", [None])[0])
        after = None
        if after_raw is not None:
            try:
                after = int(after_raw)
            except (TypeError, ValueError):
                return 400, _err("BadRequest",
                                 "after / Last-Event-ID must be an "
                                 "integer seq cursor")
            if after < 0:
                return 400, _err("BadRequest",
                                 "after must be non-negative")
        wait_raw = query.get("wait_s", [None])[0]
        wait_s = MAX_STREAM_S
        if wait_raw is not None:
            try:
                wait_s = float(wait_raw)
            except (TypeError, ValueError):
                return 400, _err("BadRequest", "wait_s must be a number")
            wait_s = min(max(wait_s, 0.0), MAX_STREAM_S)
        try:
            first = self.idds.list_events(sub_id, after_seq=after)
        except KeyError:
            return 404, _err("NotFound",
                             f"unknown subscription {sub_id!r}")

        def frames():
            cursor = after
            deadline = time.monotonic() + wait_s
            batch = first["events"]
            while True:
                for ev in batch:
                    cursor = ev["seq"]
                    yield (f"id: {ev['seq']}\nevent: delivery\n"
                           f"data: {json.dumps(ev)}\n\n")
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return
                woke = self.idds.wait_delivery_event(
                    min(remaining, SSE_HEARTBEAT_S))
                if not woke:
                    yield ": keep-alive\n\n"
                batch = self.idds.list_events(
                    sub_id, after_seq=cursor)["events"]

        return 200, SSEStream(frames())

    def handle_ack(self, sub_id: str, body: bytes,
                   token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        ids = d.get("delivery_ids")
        if (not isinstance(ids, list) or not ids
                or not all(isinstance(i, str) for i in ids)):
            return 400, _err("BadRequest",
                             "delivery_ids (non-empty string list) is "
                             "required")
        try:
            return 200, self.idds.ack_delivery(sub_id, ids)
        except KeyError as e:
            return 404, _err("NotFound",
                             e.args[0] if e.args else str(e))

    def handle_stats(self, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        return 200, self.idds.stats

    # -- telemetry plane --------------------------------------------------
    def handle_metrics(self, query: Dict[str, List[str]],
                       token: str) -> Tuple[int, Any]:
        """Prometheus text exposition; ``?cluster=1`` merges in the
        snapshots live peer heads heartbeat into the health table."""
        self.idds._auth(token)
        cluster = (query or {}).get("cluster", ["0"])[0]
        text = self.idds.metrics_text(
            cluster=cluster not in ("", "0", "false", "no"))
        return 200, PlainText(text)

    def handle_trace(self, request_id: str, token: str) -> Tuple[int, Dict]:
        """A request's reconstructed lifecycle timeline: journaled
        trace events + paired spans with durations and per-head
        attribution."""
        self.idds._auth(token)
        try:
            return 200, self.idds.trace(request_id)
        except KeyError:
            return 404, _err("NotFound", f"unknown request {request_id!r}")

    def handle_cluster(self, token: str) -> Tuple[int, Dict]:
        """Head registry for the ownership plane: every head that has
        heartbeated into the store's health table, with heartbeat age,
        liveness verdict and live workflow-claim count."""
        self.idds._auth(token)
        return 200, self.idds.cluster_info()

    # -- execution plane (pull-based workers) ----------------------------
    def _scheduler(self):
        sched = self.idds.scheduler
        if sched is None:
            raise _NotDistributed(
                "head service executes payloads inline; start it with a "
                "DistributedWFM executor (--distributed) to serve workers")
        return sched

    def handle_lease(self, body: bytes, query: Dict[str, List[str]],
                     token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        worker_id = d.get("worker_id")
        if not worker_id or not isinstance(worker_id, str):
            return 400, _err("BadRequest", "worker_id (string) is required")
        queues = d.get("queues")
        if queues is not None and (
                not isinstance(queues, list)
                or not all(isinstance(q, str) for q in queues)):
            return 400, _err("BadRequest", "queues must be a string list")
        manifest, m_err = _parse_manifest(d)
        if m_err is not None:
            return m_err
        # ?n= (or body "n") switches to the multi-lease form: up to n
        # jobs in one scheduler lock grab, {"jobs": [...], "count": k}
        n_raw = (query or {}).get("n", [d.get("n")])[0]
        n = None
        if n_raw is not None:
            try:
                n = int(n_raw)
            except (TypeError, ValueError):
                return 400, _err("BadRequest", "n must be an integer")
            if isinstance(n_raw, bool) or not 1 <= n <= MAX_LEASE_BATCH:
                return 400, _err(
                    "BadRequest",
                    f"n must be between 1 and {MAX_LEASE_BATCH}")
        try:
            ttl = (None if d.get("lease_ttl") is None
                   else float(d["lease_ttl"]))
            sched = self._scheduler()
            if n is None:
                job = sched.lease(
                    worker_id, queues=queues, ttl=ttl,
                    idempotency_key=d.get("idempotency_key"),
                    manifest=manifest)
                return 200, {"job": job}
            jobs = sched.lease_many(
                worker_id, n=n, queues=queues, ttl=ttl,
                idempotency_key=d.get("idempotency_key"),
                manifest=manifest)
        except (TypeError, ValueError) as e:
            return 400, _err("BadRequest", f"malformed lease request: {e}")
        return 200, {"jobs": jobs, "count": len(jobs)}

    def handle_jobs_heartbeat(self, body: bytes,
                              token: str) -> Tuple[int, Dict]:
        """Batch lease renewal: one 200 response with per-item status
        envelopes, so one stale lease cannot poison the batch."""
        self.idds._auth(token)
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        worker_id = d.get("worker_id")
        if not worker_id or not isinstance(worker_id, str):
            return 400, _err("BadRequest", "worker_id (string) is required")
        job_ids = d.get("job_ids")
        if (not isinstance(job_ids, list) or not job_ids
                or not all(isinstance(j, str) and j for j in job_ids)):
            return 400, _err("BadRequest",
                             "job_ids (non-empty string list) is required")
        if len(job_ids) > MAX_BATCH_ITEMS:
            return 400, _err("BadRequest",
                             f"at most {MAX_BATCH_ITEMS} job_ids per batch")
        manifest, m_err = _parse_manifest(d)
        if m_err is not None:
            return m_err
        results = self._scheduler().heartbeat_many(worker_id, job_ids,
                                                   manifest=manifest)
        return 200, batch_envelope(_job_batch_items(results))

    def handle_jobs_complete(self, body: bytes,
                             token: str) -> Tuple[int, Dict]:
        """Batch outcome reporting with per-item status envelopes."""
        self.idds._auth(token)
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        worker_id = d.get("worker_id")
        if not worker_id or not isinstance(worker_id, str):
            return 400, _err("BadRequest", "worker_id (string) is required")
        items = d.get("items")
        if not isinstance(items, list) or not items:
            return 400, _err("BadRequest",
                             "items (non-empty list) is required")
        if len(items) > MAX_BATCH_ITEMS:
            return 400, _err("BadRequest",
                             f"at most {MAX_BATCH_ITEMS} items per batch")
        triples = []
        for it in items:
            if not isinstance(it, dict) or not isinstance(
                    it.get("job_id"), str) or not it.get("job_id"):
                return 400, _err("BadRequest",
                                 "each item needs a job_id (string)")
            result = it.get("result")
            if result is not None and not isinstance(result, dict):
                return 400, _err("BadRequest", "result must be an object")
            error = it.get("error")
            if error is not None and not isinstance(error, str):
                return 400, _err("BadRequest", "error must be a string")
            triples.append((it["job_id"], result, error))
        results = self._scheduler().complete_many(worker_id, triples)
        return 200, batch_envelope(_job_batch_items(results))

    def handle_job_heartbeat(self, job_id: str, body: bytes,
                             token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        worker_id = d.get("worker_id")
        if not worker_id or not isinstance(worker_id, str):
            return 400, _err("BadRequest", "worker_id (string) is required")
        manifest, m_err = _parse_manifest(d)
        if m_err is not None:
            return m_err
        return 200, self._scheduler().heartbeat(job_id, worker_id,
                                                manifest=manifest)

    def handle_job_complete(self, job_id: str, body: bytes,
                            token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        worker_id = d.get("worker_id")
        if not worker_id or not isinstance(worker_id, str):
            return 400, _err("BadRequest", "worker_id (string) is required")
        result = d.get("result")
        if result is not None and not isinstance(result, dict):
            return 400, _err("BadRequest", "result must be an object")
        error = d.get("error")
        if error is not None and not isinstance(error, str):
            return 400, _err("BadRequest", "error must be a string")
        return 200, self._scheduler().complete(
            job_id, worker_id, result=result, error=error)

    def handle_workers(self, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        sched = self.idds.scheduler
        if sched is None:
            return 200, {"workers": [], "connected": 0,
                         "distributed": False}
        return 200, {"workers": sched.workers(),
                     "connected": sched.worker_count(),
                     "distributed": True,
                     "queues": sched.queue_depths()}

    def handle_queues(self, token: str) -> Tuple[int, Dict]:
        """Per-queue scheduler state: depth, suspended count, base and
        effective priority (aging + adaptive boost when intel is on),
        learned completion rate."""
        self.idds._auth(token)
        sched = self.idds.scheduler
        if sched is None:
            return 200, {"queues": {}, "distributed": False}
        return 200, {"queues": sched.queue_stats(), "distributed": True,
                     "intel": sched.intel is not None}

    def handle_intel(self, token: str) -> Tuple[int, Dict]:
        """Intelligence-plane introspection: affinity hit-rate, learned
        per-queue history, hedge/rescore counters.  Answers with
        ``enabled: false`` (not an error) on inline or --intel off
        heads so dashboards can poll unconditionally."""
        self.idds._auth(token)
        sched = self.idds.scheduler
        intel = None if sched is None else sched.intel
        if intel is None:
            return 200, {"enabled": False,
                         "distributed": sched is not None}
        out = intel.snapshot()
        out.update({"enabled": True, "distributed": True})
        return 200, out

    def _delivery_tallies(self) -> Tuple[Dict, Dict]:
        ts, contents, deliveries = self._tally_cache
        now = time.monotonic()
        if contents is None or now - ts > self._tally_ttl:
            contents = self.idds.content_stats()
            deliveries = self.idds.delivery_stats()
            self._tally_cache = (now, contents, deliveries)
        return contents, deliveries

    def handle_healthz(self) -> Tuple[int, Dict]:
        sched = self.idds.scheduler
        contents, deliveries = self._delivery_tallies()
        return 200, {
            "status": "ok",
            # head identity: which cluster member answered this probe,
            # and over which bus backend it coordinates with its peers
            "head_id": self.idds.ctx.head_id,
            "bus": getattr(self.idds.ctx.bus, "name", "local"),
            "daemons": self.idds.daemon_liveness(),
            "store": type(self.idds.store).__name__,
            "distributed": sched is not None,
            "workers_connected": (sched.worker_count()
                                  if sched is not None else 0),
            # operators spot a wedged command/execution plane here: a
            # growing pending_commands or an all-suspended queue
            "queues": (sched.queue_depths() if sched is not None else {}),
            "pending_commands": self.idds.pending_commands(),
            # delivery plane at a glance: per-status content tallies
            # across every collection + subscription/delivery counters
            # (cached ~1s; see _delivery_tallies)
            "contents": contents,
            "deliveries": deliveries,
            "uptime_s": (round(time.time() - self.started_at, 3)
                         if self.started_at else 0.0),
        }


class PlainText:
    """Marks a handler body as pre-rendered text (Prometheus
    exposition): ``_reply`` sends it verbatim instead of JSON."""
    __slots__ = ("text", "content_type")

    def __init__(self, text: str,
                 content_type: str = "text/plain; version=0.0.4"):
        self.text = text
        self.content_type = content_type


class SSEStream:
    """Marks a handler body as a Server-Sent Events stream: ``_reply``
    sends no Content-Length, flushes each frame as the generator yields
    it, and closes the connection when the generator ends (the handler
    decides the stream's lifetime).  Frames are pre-formatted SSE text
    (``id:``/``event:``/``data:`` lines, blank-line terminated)."""
    __slots__ = ("frames",)

    def __init__(self, frames):
        self.frames = frames


def _err(type_: str, message: str) -> Dict[str, Dict[str, str]]:
    return {"error": {"type": type_, "message": message}}


def _parse_page(query: Dict[str, List[str]]):
    """``?limit=&offset=`` -> (limit, offset, None) or
    (None, None, (400, envelope)) — the one paginated-collection
    parser, shared by every listing route."""
    try:
        limit_s = (query or {}).get("limit", [None])[0]
        limit = None if limit_s is None else int(limit_s)
        offset = int((query or {}).get("offset", ["0"])[0])
    except (TypeError, ValueError):
        return None, None, (400, _err("BadRequest",
                                      "limit and offset must be integers"))
    return limit, offset, None


def batch_envelope(results: List[Dict[str, Any]], *,
                   ok_key: str = "ok",
                   **extra: Any) -> Dict[str, Any]:
    """The ONE wire shape for every batch verb (``jobs/heartbeat``,
    ``jobs/complete``, ``contents:transition``): per-item envelopes
    under ``results`` plus top-level ``ok``/``failed`` tallies, so a
    single bad item can never poison the batch.  ``ok_key`` names the
    per-item success flag (``"ok"`` for scheduler verbs, ``"applied"``
    for content transitions); ``extra`` carries verb-specific totals
    (e.g. ``applied``/``skipped``) into the top level.  Mirrored
    client-side by :class:`repro.core.client.BatchResult`."""
    ok = sum(1 for r in results if r.get(ok_key))
    env: Dict[str, Any] = {"results": results, "ok": ok,
                           "failed": len(results) - ok}
    env.update(extra)
    return env


def _job_batch_items(results: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Scheduler per-item results -> wire items: each carries its own
    ``status`` (200 or 409) and, on failure, the same
    ``{"error": {"type", "message"}}`` shape as a top-level error."""
    items = []
    for r in results:
        if r.get("ok"):
            item = dict(r)
            item["status"] = 200
            items.append(item)
        else:
            items.append({"job_id": r["job_id"], "ok": False,
                          "status": 409,
                          "error": {"type": "Conflict",
                                    "message": r["error"]}})
    return items


class _NotDistributed(Exception):
    """A /jobs call reached a head running the inline executor."""


def _parse_json_object(body: bytes):
    """Decode a request body as a JSON object; empty body -> {}.
    Returns ``(obj, None)`` or ``(None, (status, envelope))``."""
    if not body:
        return {}, None
    try:
        d = json.loads(body.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        return None, (400, _err("BadRequest",
                                f"request body is not JSON: {e}"))
    if not isinstance(d, dict):
        return None, (400, _err("BadRequest",
                                "request body must be a JSON object"))
    return d, None


def _parse_manifest(d: Dict):
    """Optional worker cache manifest on lease/heartbeat bodies.
    Returns ``(names_or_None, None)`` or ``(None, (status, envelope))``."""
    manifest = d.get("manifest")
    if manifest is None:
        return None, None
    if (not isinstance(manifest, list)
            or not all(isinstance(n, str) for n in manifest)):
        return None, (400, _err("BadRequest",
                                "manifest must be a string list"))
    if len(manifest) > MAX_MANIFEST_ITEMS:
        # keep the freshest (a worker LRU reports oldest-first)
        manifest = manifest[-MAX_MANIFEST_ITEMS:]
    return manifest, None


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

API_PREFIX = "/v1"

# (method, path-pattern relative to the mount, handler, has-legacy-alias).
# Order matters: more specific patterns first.  Routes with legacy=True
# predate the /v1 namespace and stay mounted unversioned as deprecated
# aliases; v1-only resources (commands/transforms/processings) do not.
_ROUTE_SPECS = [
    ("POST", r"requests/?", "handle_submit", True),
    ("GET", r"requests/?", "handle_list", True),
    ("POST", r"jobs/lease/?", "handle_lease", True),
    # batch verbs first: "heartbeat"/"complete" must not be captured as
    # a job_id by the per-job routes below
    ("POST", r"jobs/heartbeat/?", "handle_jobs_heartbeat", False),
    ("POST", r"jobs/complete/?", "handle_jobs_complete", False),
    ("POST", r"jobs/(?P<job_id>[^/]+)/heartbeat/?",
     "handle_job_heartbeat", True),
    ("POST", r"jobs/(?P<job_id>[^/]+)/complete/?",
     "handle_job_complete", True),
    ("GET", r"workers/?", "handle_workers", True),
    ("GET", r"queues/?", "handle_queues", False),
    ("GET", r"intel/?", "handle_intel", False),
    ("POST", r"requests/(?P<request_id>[^/]+)/commands/?",
     "handle_command_submit", False),
    ("GET", r"requests/(?P<request_id>[^/]+)/commands/"
     r"(?P<command_id>[^/]+)/?", "handle_command_get", False),
    ("GET", r"requests/(?P<request_id>[^/]+)/commands/?",
     "handle_command_list", False),
    ("GET", r"requests/(?P<request_id>[^/]+)/transforms/?",
     "handle_transforms", False),
    ("GET", r"requests/(?P<request_id>[^/]+)/processings/?",
     "handle_processings", False),
    ("GET", r"requests/(?P<request_id>[^/]+)/workflow/?",
     "handle_workflow", True),
    ("GET", r"requests/(?P<request_id>[^/]+)/trace/?",
     "handle_trace", False),
    ("GET", r"requests/(?P<request_id>[^/]+)/?", "handle_status", True),
    ("POST", r"subscriptions/?", "handle_subscribe", False),
    ("POST", r"subscriptions/(?P<sub_id>[^/]+)/ack/?",
     "handle_ack", False),
    ("GET", r"subscriptions/(?P<sub_id>[^/]+)/deliveries/?",
     "handle_deliveries", False),
    ("GET", r"subscriptions/(?P<sub_id>[^/]+)/events/?",
     "handle_events", False),
    ("GET", r"subscriptions/(?P<sub_id>[^/]+)/?",
     "handle_subscription", False),
    ("GET", r"subscriptions/?", "handle_subscriptions", False),
    ("GET", r"collections/?", "handle_collections", False),
    ("POST", r"collections/(?P<name>.+)/contents:transition/?",
     "handle_contents_transition", False),
    ("GET", r"collections/(?P<name>.+)/contents/?",
     "handle_contents", True),
    ("GET", r"collections/(?P<name>.+?)/?", "handle_collection", True),
    ("GET", r"stats/?", "handle_stats", True),
    ("GET", r"metrics/?", "handle_metrics", False),
    ("GET", r"cluster/?", "handle_cluster", False),
    ("GET", r"healthz/?", "handle_healthz", True),
]

# (method, compiled-regex, gateway-method, deprecated) — the v1 mounts
# first (canonical), then the legacy aliases that answer with a
# Deprecation header pointing at their v1 successor.
_ROUTES = [
    (m, re.compile(f"^{re.escape(API_PREFIX)}/{pat}$"), fn, False)
    for m, pat, fn, _legacy in _ROUTE_SPECS
] + [
    (m, re.compile(f"^/{pat}$"), fn, True)
    for m, pat, fn, legacy in _ROUTE_SPECS if legacy
]


def _make_handler(gw: RestGateway):
    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"
        server_version = "idds-rest/1.0"

        # -- plumbing ----------------------------------------------------
        def log_message(self, fmt, *args):  # noqa: A003
            if not gw.quiet:
                BaseHTTPRequestHandler.log_message(self, fmt, *args)

        def _token(self) -> str:
            auth = self.headers.get("Authorization", "")
            if auth.lower().startswith("bearer "):
                return auth[7:].strip()
            return self.headers.get("X-IDDS-Token", "")

        def _drain_body(self) -> None:
            """Consume any unread request body before replying: leaving
            bytes on a keep-alive connection desyncs the next request."""
            if getattr(self, "_body_consumed", False):
                return
            self._body_consumed = True
            length = int(self.headers.get("Content-Length", 0) or 0)
            if length <= 0:
                return
            if length > MAX_BODY_BYTES:
                self.close_connection = True  # cheaper than reading it
                return
            while length > 0:
                chunk = self.rfile.read(min(length, 65536))
                if not chunk:
                    break
                length -= len(chunk)

        def _reply(self, status: int, body: Any,
                   headers: Optional[List[Tuple[str, str]]] = None) -> None:
            self._drain_body()
            if isinstance(body, SSEStream):
                # streaming: no Content-Length, so the connection must
                # close when the generator ends (HTTP/1.1 framing)
                self.close_connection = True
                self.send_response(status)
                self.send_header("Content-Type", "text/event-stream")
                self.send_header("Cache-Control", "no-cache")
                for k, v in headers or ():
                    self.send_header(k, v)
                self.send_header("Connection", "close")
                self.end_headers()
                try:
                    for frame in body.frames:
                        self.wfile.write(frame.encode("utf-8"))
                        self.wfile.flush()
                except (BrokenPipeError, ConnectionResetError, OSError):
                    pass  # consumer hung up mid-stream; nothing to do
                return
            if isinstance(body, PlainText):
                payload = body.text.encode("utf-8")
                content_type = body.content_type
            else:
                payload = json.dumps(body).encode("utf-8")
                content_type = "application/json"
            self.send_response(status)
            self.send_header("Content-Type", content_type)
            self.send_header("Content-Length", str(len(payload)))
            for k, v in headers or ():
                self.send_header(k, v)
            if self.close_connection:
                self.send_header("Connection", "close")
            self.end_headers()
            self.wfile.write(payload)

        def _dispatch(self, method: str) -> None:
            # one handler instance serves every request on a keep-alive
            # connection: reset the per-request drain marker, or the
            # second bodied request would never be drained (desync)
            self._body_consumed = False
            # Route on the still-quoted path; unquote captured segments in
            # _invoke so %2F inside a collection name survives routing.
            path = urllib.parse.urlsplit(self.path).path
            allowed: List[str] = []
            for m, rx, fn_name, deprecated in _ROUTES:
                match = rx.match(path)
                if match is None:
                    continue
                if m != method:
                    if m not in allowed:
                        allowed.append(m)
                    continue
                headers: List[Tuple[str, str]] = []
                if deprecated:
                    successor = f"{API_PREFIX}{path}"
                    if (gw.legacy_routes == "off"
                            and fn_name != "handle_healthz"):
                        # cutover mode: the unversioned surface is
                        # retired — 410 (not 404: the route existed)
                        # with a machine-readable pointer to /v1.
                        # /healthz stays answering: liveness probes in
                        # deployment manifests predate versioning.
                        body = _err(
                            "Gone",
                            f"unversioned route removed; use "
                            f"{successor}")
                        body["error"]["successor"] = successor
                        self._reply(410, body, [
                            ("Link", f'<{successor}>; '
                                     f'rel="successor-version"')])
                        return
                    # warn mode: same behaviour, but tell clients
                    # where the stable surface lives
                    headers.append(("Deprecation", "true"))
                    headers.append(("Link",
                                    f'<{successor}>; '
                                    f'rel="successor-version"'))
                t0 = time.monotonic()
                try:
                    status, body = self._invoke(fn_name, match)
                except AuthError as e:
                    status, body = 401, _err("AuthError", str(e))
                except (SchedulerConflict, CommandConflict) as e:
                    status, body = 409, _err("Conflict", str(e))
                except _NotDistributed as e:
                    status, body = 400, _err("NotDistributed", str(e))
                except Exception as e:  # noqa: BLE001 — envelope, not trace
                    status, body = 500, _err(type(e).__name__, str(e))
                route = fn_name[7:]  # strip "handle_"
                gw._obs_req_hist.labels(route=route).observe(
                    time.monotonic() - t0)
                gw._obs_req_count.labels(route=route,
                                         status=str(status)).inc()
                self._reply(status, body, headers)
                return
            if allowed:
                # known path, wrong method: an Allow header tells the
                # client what would have worked (RFC 9110 §15.5.6)
                self._reply(405, _err("MethodNotAllowed",
                                      f"{method} not allowed on {path}"),
                            [("Allow", ", ".join(sorted(set(allowed))))])
            else:
                self._reply(404, _err("NotFound", f"no route for {path}"))

        # handlers that consume the request body (all POST routes)
        _BODY_HANDLERS = frozenset({
            "handle_submit", "handle_lease", "handle_job_heartbeat",
            "handle_job_complete", "handle_jobs_heartbeat",
            "handle_jobs_complete", "handle_contents_transition",
            "handle_command_submit", "handle_subscribe", "handle_ack"})
        # handlers that read the query string (filters / pagination /
        # the ?n= multi-lease switch); may overlap with _BODY_HANDLERS
        _QUERY_HANDLERS = frozenset({
            "handle_list", "handle_contents", "handle_deliveries",
            "handle_lease", "handle_metrics", "handle_subscriptions",
            "handle_events"})

        def _invoke(self, fn_name: str, match) -> Tuple[int, Any]:
            token = self._token()
            if fn_name == "handle_healthz":
                return gw.handle_healthz()
            kwargs = {k: urllib.parse.unquote(v)
                      for k, v in match.groupdict().items()}
            if fn_name == "handle_events":
                # the SSE resume cursor EventSource re-sends on reconnect
                kwargs["last_event_id"] = self.headers.get("Last-Event-ID")
            if fn_name in self._QUERY_HANDLERS:
                kwargs["query"] = urllib.parse.parse_qs(
                    urllib.parse.urlsplit(self.path).query)
            if fn_name in self._BODY_HANDLERS:
                length = int(self.headers.get("Content-Length", 0))
                if length > MAX_BODY_BYTES:
                    self._body_consumed = True
                    self.close_connection = True  # body left unread
                    return 413, _err("PayloadTooLarge",
                                     f"body exceeds {MAX_BODY_BYTES} bytes")
                body = self.rfile.read(length)
                self._body_consumed = True
                return getattr(gw, fn_name)(body=body, token=token,
                                            **kwargs)
            if fn_name == "handle_stats":
                return gw.handle_stats(token)
            return getattr(gw, fn_name)(**kwargs, token=token)

        # -- verbs -------------------------------------------------------
        def do_GET(self):  # noqa: N802
            self._dispatch("GET")

        def do_POST(self):  # noqa: N802
            self._dispatch("POST")

        # other verbs get the JSON 405/404 envelope, not stock HTML
        def do_PUT(self):  # noqa: N802
            self._dispatch("PUT")

        def do_DELETE(self):  # noqa: N802
            self._dispatch("DELETE")

        def do_PATCH(self):  # noqa: N802
            self._dispatch("PATCH")

    return Handler


# ---------------------------------------------------------------------------
# CLI entrypoint
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.rest",
        description="Serve the iDDS head service over HTTP.")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8443)
    ap.add_argument("--tokens", default=None,
                    help="comma-separated bearer tokens (omit = auth off)")
    ap.add_argument("--async-wfm", action="store_true",
                    help="run payloads on a WFM worker pool instead of "
                         "inline in the Carrier thread")
    ap.add_argument("--distributed", action="store_true",
                    help="dispatch payloads to pull-based remote workers "
                         "(python -m repro.worker) via the lease "
                         "scheduler instead of executing them in-process")
    ap.add_argument("--lease-ttl", type=float, default=30.0,
                    help="seconds a worker lease lives between "
                         "heartbeats (--distributed)")
    ap.add_argument("--intel", choices=("on", "off"), default="off",
                    help="intelligence plane (--distributed): score "
                         "lease candidates by worker cache affinity and "
                         "learned per-queue completion rates, hedge "
                         "stragglers against the learned staging p95, "
                         "and adapt queue priorities; 'off' keeps the "
                         "legacy FIFO-within-priority dispatch")
    ap.add_argument("--max-workers", type=int, default=8)
    ap.add_argument("--payloads", action="append", default=[],
                    help="importable module that registers payloads "
                         "(repeatable)")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="SQLite file for durable state; requests in "
                         "flight at a crash are recovered on restart "
                         "(omit = in-memory, nothing survives)")
    ap.add_argument("--store-flush-ms", type=float, default=None,
                    metavar="MS",
                    help="coalesce content/lease journal writes into "
                         "batched commits flushed every MS milliseconds "
                         "(bulk hot path; at most MS ms of those rows "
                         "can be lost in a crash — see "
                         "docs/architecture.md)")
    ap.add_argument("--store-max-batch", type=int, default=256,
                    help="flush the write-coalescing buffer early once "
                         "it holds this many ops (--store-flush-ms)")
    ap.add_argument("--bus", choices=("local", "store"), default="local",
                    help="message bus backend: 'local' is the "
                         "in-process queue (single head); 'store' "
                         "polls a bus table in the shared store so "
                         "several heads can pump one catalog "
                         "(multi-head; pair with --store)")
    ap.add_argument("--head-id", default=None, metavar="ID",
                    help="stable identity of this head in the "
                         "ownership plane (omit = random head-<hex>)")
    ap.add_argument("--claim-ttl", type=float, default=5.0,
                    metavar="SECONDS",
                    help="workflow-claim lease: a head that misses "
                         "renewals for this long loses its claims to "
                         "a peer's watchdog sweep")
    ap.add_argument("--legacy-routes", choices=("warn", "off"),
                    default="warn",
                    help="pre-v1 unversioned paths: 'warn' serves them "
                         "with Deprecation/Link headers; 'off' retires "
                         "them with 410 Gone pointing at /v1 "
                         "(/healthz stays as a probe alias)")
    ap.add_argument("--carousel", action="store_true",
                    help="mount a CarouselDDM (synthetic ColdStore + "
                         "DiskCache) as the DDM backend and start "
                         "staging the demo collection: file-backed "
                         "fine-granularity works dispatch per-file as "
                         "shards land")
    ap.add_argument("--carousel-collection", default="tape",
                    metavar="NAME",
                    help="collection name the carousel registers and "
                         "stages (--carousel)")
    ap.add_argument("--carousel-shards", type=int, default=8,
                    help="number of synthetic tape shards (--carousel)")
    ap.add_argument("--carousel-latency", type=float, default=0.05,
                    help="tape mount latency per shard read in seconds "
                         "(--carousel)")
    ap.add_argument("--verbose", action="store_true",
                    help="log each HTTP request")
    ap.add_argument("--log-level", default="INFO",
                    choices=("DEBUG", "INFO", "WARNING", "ERROR"),
                    help="threshold for the structured core logs "
                         "(daemon faults, slow-op warnings)")
    ap.add_argument("--log-json", action="store_true",
                    help="emit core logs as one JSON object per line "
                         "(for log shippers) instead of text")
    args = ap.parse_args(argv)

    for mod in args.payloads:
        importlib.import_module(mod)

    tokens = (set(t for t in args.tokens.split(",") if t)
              if args.tokens else None)
    store = SqliteStore(args.store) if args.store else None
    if store is not None and args.store_flush_ms is not None:
        store = BufferedStore(store, flush_interval_ms=args.store_flush_ms,
                              max_batch=args.store_max_batch)
    executor = (DistributedWFM(lease_ttl=args.lease_ttl,
                               intel=args.intel == "on")
                if args.distributed else None)
    ddm = None
    if args.carousel:
        # numpy-backed synthetic corpus; imported lazily so a plain
        # head stays stdlib-only
        from repro.carousel.ddm import CarouselDDM
        from repro.carousel.storage import DiskCache
        from repro.data.synthetic import build_cold_store
        cold = build_cold_store(n_shards=args.carousel_shards, drives=2,
                                mount_latency=args.carousel_latency)
        ddm = CarouselDDM(cold, DiskCache(1 << 30))
    idds = IDDS(sync=not args.async_wfm, max_workers=args.max_workers,
                tokens=tokens, store=store, executor=executor, ddm=ddm,
                bus=args.bus, head_id=args.head_id,
                claim_ttl=args.claim_ttl)
    setup_logging(args.log_level, args.log_json, idds.ctx.head_id)
    if store is not None and args.bus != "store":
        counts = idds.recover()
        recovered = {k: v for k, v in counts.items() if v}
        if recovered:
            print(f"idds-rest recovered state from {args.store}: "
                  f"{recovered}", flush=True)
    elif store is not None:
        # multi-head join: a full recover() is TAKEOVER semantics (it
        # steals live claims), which would hijack a running peer's
        # work.  A joining head instead lets its watchdog sweep adopt
        # whatever claims expire — the single-head-restart case heals
        # the same way, one claim TTL after the old head died.
        print(f"idds-rest joining cluster on {args.store} as "
              f"{idds.ctx.head_id} (watchdog adopts orphaned work)",
              flush=True)
    if args.carousel:
        # a recovered store may have re-registered the collection with
        # its journaled per-file state; don't clobber it
        if args.carousel_collection not in ddm.list_collections():
            ddm.register_from_cold(args.carousel_collection)
        coll = ddm.get_collection(args.carousel_collection)
        ddm.stage_collection(args.carousel_collection)
        print(f"carousel: staging {len(coll.files)} shards into "
              f"collection {args.carousel_collection!r}", flush=True)
    gw = RestGateway(idds, host=args.host, port=args.port,
                     quiet=not args.verbose,
                     legacy_routes=args.legacy_routes)

    # SIGINT/SIGTERM flip an event instead of killing the process
    # mid-write: the daemons drain, the HTTP server closes, and the
    # store is closed cleanly (WAL checkpointed) before exit.
    stop_evt = threading.Event()

    def _on_signal(signum, frame):
        stop_evt.set()

    signal.signal(signal.SIGINT, _on_signal)
    signal.signal(signal.SIGTERM, _on_signal)

    gw.start()
    wfm_mode = ("distributed" if args.distributed else
                "async" if args.async_wfm else "sync")
    if args.distributed and args.intel == "on":
        wfm_mode += "+intel"
    print(f"idds-rest serving on {gw.url} "
          f"(auth={'on' if tokens else 'off'}, "
          f"wfm={wfm_mode}, "
          f"store={args.store or 'memory'}, "
          f"bus={args.bus}, head={idds.ctx.head_id})", flush=True)
    try:
        stop_evt.wait()
        print("signal received: shutting down", flush=True)
    finally:
        gw.stop()       # HTTP server down, then daemons stopped
        idds.close()    # store closed last, after the final writes
        print("idds-rest stopped (daemons stopped, store closed)",
              flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
