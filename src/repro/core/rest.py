"""REST gateway for the iDDS head service (paper §2).

The paper describes iDDS as "a general Restful service to receive
requests from WFMS" — this module is that network boundary.  It wraps an
in-process :class:`repro.core.idds.IDDS` in a thread-pooled stdlib HTTP
server so workflows can be submitted and tracked over the wire by any
client speaking JSON (see :mod:`repro.core.client` for the typed SDK).

Endpoints (all JSON; details in docs/rest_api.md):

  POST /requests                     submit a serialized Request
  GET  /requests                     catalog listing (status filter,
                                     limit/offset pagination)
  GET  /requests/<id>                request status + work counts
  GET  /requests/<id>/workflow       full workflow state (the DG)
  GET  /collections/<name>           collection metadata
  GET  /collections/<name>/contents  per-file availability
  POST /jobs/lease                   worker: lease the next job
  POST /jobs/<id>/heartbeat          worker: renew a held lease
  POST /jobs/<id>/complete           worker: report result or error
  GET  /workers                      execution-plane worker registry
  GET  /stats                        daemon counters
  GET  /healthz                      liveness + store backend + daemon
                                     liveness + connected-worker count
                                     (never requires auth)

The /jobs endpoints are the pull-based execution plane (paper's pilot
model): they 400 with type ``NotDistributed`` unless the head runs a
``DistributedWFM`` executor, and lease-validation failures (expired or
reassigned leases) are 409 envelopes with type ``Conflict``.

Auth: a bearer token (``Authorization: Bearer <t>`` or ``X-IDDS-Token``)
checked against the IDDS token set; failures surface as the same
``AuthError`` the in-process facade raises and map to HTTP 401.  Every
error is a JSON envelope ``{"error": {"type": ..., "message": ...}}``.

Run standalone:

    PYTHONPATH=src python -m repro.core.rest --port 8443 \
        --tokens s3cret --payloads my_payload_module
"""
from __future__ import annotations

import argparse
import importlib
import json
import re
import signal
import threading
import time
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.idds import IDDS, AuthError
from repro.core.scheduler import DistributedWFM, SchedulerConflict
from repro.core.store import SqliteStore

MAX_BODY_BYTES = 16 * 1024 * 1024  # refuse absurd submissions


class RestGateway:
    """HTTP front-end owning the lifecycle of an IDDS head service.

    ``start()`` spins the IDDS daemon threads and then the HTTP server;
    ``stop()`` tears both down in reverse order.  Also usable as a
    context manager.  ``port=0`` binds an ephemeral port (tests).
    """

    def __init__(self, idds: Optional[IDDS] = None, *,
                 host: str = "127.0.0.1", port: int = 0,
                 tokens: Optional[Set[str]] = None,
                 manage_idds: bool = True, quiet: bool = True):
        self.idds = idds if idds is not None else IDDS(tokens=tokens)
        if tokens is not None and idds is not None:
            self.idds._tokens = set(tokens)
        self.host = host
        self._requested_port = port
        self.manage_idds = manage_idds
        self.quiet = quiet
        self.started_at: Optional[float] = None
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ lifecycle
    @property
    def port(self) -> int:
        if self._httpd is None:
            return self._requested_port
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "RestGateway":
        if self._httpd is not None:
            raise RuntimeError("gateway already started")
        if self.manage_idds:
            self.idds.start()
        handler = _make_handler(self)
        server_cls = type("IDDSHTTPServer", (ThreadingHTTPServer,), {
            # urllib clients open a fresh connection per call: the default
            # listen backlog of 5 drops SYNs under concurrent load (1s
            # retransmit stalls in benchmarks)
            "request_queue_size": 128,
        })
        self._httpd = server_cls((self.host, self._requested_port), handler)
        self._httpd.daemon_threads = True
        # small JSON responses: Nagle + delayed ACK costs ~40ms per poll
        self._httpd.disable_nagle_algorithm = True
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="idds-rest", daemon=True)
        self._thread.start()
        self.started_at = time.time()
        return self

    def stop(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        if self.manage_idds:
            self.idds.stop()

    def __enter__(self) -> "RestGateway":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ handlers
    # Each returns (http_status, json-serializable body).
    def handle_submit(self, body: bytes, token: str) -> Tuple[int, Dict]:
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        if "workflow" not in d:
            return 400, _err("BadRequest",
                             "body must be a Request object with a "
                             "'workflow' field")
        if token and not d.get("token"):
            d["token"] = token  # header auth wins over an empty body token
        try:
            request_id = self.idds.submit(json.dumps(d))
        except AuthError as e:
            return 401, _err("AuthError", str(e))
        except (KeyError, TypeError, ValueError) as e:
            return 400, _err("BadRequest", f"malformed request: {e}")
        return 201, {"request_id": request_id, "status": "accepted"}

    def handle_list(self, query: Dict[str, List[str]],
                    token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        status = query.get("status", [None])[0]
        try:
            limit_s = query.get("limit", [None])[0]
            offset_s = query.get("offset", ["0"])[0]
            limit = None if limit_s is None else int(limit_s)
            offset = int(offset_s)
        except (TypeError, ValueError):
            return 400, _err("BadRequest",
                             "limit and offset must be integers")
        try:
            return 200, self.idds.list_requests(status=status, limit=limit,
                                                offset=offset)
        except ValueError as e:
            return 400, _err("BadRequest", str(e))

    def handle_status(self, request_id: str, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        try:
            return 200, self.idds.request_status(request_id)
        except KeyError:
            return 404, _err("NotFound", f"unknown request {request_id!r}")

    def handle_workflow(self, request_id: str, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        try:
            return 200, self.idds.workflow_dict(request_id)
        except KeyError:
            return 404, _err("NotFound", f"unknown request {request_id!r}")

    def handle_collection(self, name: str, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        try:
            return 200, self.idds.lookup_collection(name)
        except KeyError:
            return 404, _err("NotFound", f"unknown collection {name!r}")

    def handle_contents(self, name: str, token: str) -> Tuple[int, Any]:
        self.idds._auth(token)
        try:
            return 200, self.idds.lookup_contents(name)
        except KeyError:
            return 404, _err("NotFound", f"unknown collection {name!r}")

    def handle_stats(self, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        return 200, self.idds.stats

    # -- execution plane (pull-based workers) ----------------------------
    def _scheduler(self):
        sched = self.idds.scheduler
        if sched is None:
            raise _NotDistributed(
                "head service executes payloads inline; start it with a "
                "DistributedWFM executor (--distributed) to serve workers")
        return sched

    def handle_lease(self, body: bytes, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        worker_id = d.get("worker_id")
        if not worker_id or not isinstance(worker_id, str):
            return 400, _err("BadRequest", "worker_id (string) is required")
        queues = d.get("queues")
        if queues is not None and (
                not isinstance(queues, list)
                or not all(isinstance(q, str) for q in queues)):
            return 400, _err("BadRequest", "queues must be a string list")
        try:
            ttl = (None if d.get("lease_ttl") is None
                   else float(d["lease_ttl"]))
            job = self._scheduler().lease(
                worker_id, queues=queues, ttl=ttl,
                idempotency_key=d.get("idempotency_key"))
        except (TypeError, ValueError) as e:
            return 400, _err("BadRequest", f"malformed lease request: {e}")
        return 200, {"job": job}

    def handle_job_heartbeat(self, job_id: str, body: bytes,
                             token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        worker_id = d.get("worker_id")
        if not worker_id or not isinstance(worker_id, str):
            return 400, _err("BadRequest", "worker_id (string) is required")
        return 200, self._scheduler().heartbeat(job_id, worker_id)

    def handle_job_complete(self, job_id: str, body: bytes,
                            token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        d, err = _parse_json_object(body)
        if err is not None:
            return err
        worker_id = d.get("worker_id")
        if not worker_id or not isinstance(worker_id, str):
            return 400, _err("BadRequest", "worker_id (string) is required")
        result = d.get("result")
        if result is not None and not isinstance(result, dict):
            return 400, _err("BadRequest", "result must be an object")
        error = d.get("error")
        if error is not None and not isinstance(error, str):
            return 400, _err("BadRequest", "error must be a string")
        return 200, self._scheduler().complete(
            job_id, worker_id, result=result, error=error)

    def handle_workers(self, token: str) -> Tuple[int, Dict]:
        self.idds._auth(token)
        sched = self.idds.scheduler
        if sched is None:
            return 200, {"workers": [], "connected": 0,
                         "distributed": False}
        return 200, {"workers": sched.workers(),
                     "connected": sched.worker_count(),
                     "distributed": True,
                     "queues": sched.queue_depths()}

    def handle_healthz(self) -> Tuple[int, Dict]:
        sched = self.idds.scheduler
        return 200, {
            "status": "ok",
            "daemons": self.idds.daemon_liveness(),
            "store": type(self.idds.store).__name__,
            "distributed": sched is not None,
            "workers_connected": (sched.worker_count()
                                  if sched is not None else 0),
            "uptime_s": (round(time.time() - self.started_at, 3)
                         if self.started_at else 0.0),
        }


def _err(type_: str, message: str) -> Dict[str, Dict[str, str]]:
    return {"error": {"type": type_, "message": message}}


class _NotDistributed(Exception):
    """A /jobs call reached a head running the inline executor."""


def _parse_json_object(body: bytes):
    """Decode a request body as a JSON object; empty body -> {}.
    Returns ``(obj, None)`` or ``(None, (status, envelope))``."""
    if not body:
        return {}, None
    try:
        d = json.loads(body.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        return None, (400, _err("BadRequest",
                                f"request body is not JSON: {e}"))
    if not isinstance(d, dict):
        return None, (400, _err("BadRequest",
                                "request body must be a JSON object"))
    return d, None


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

# (method, compiled-path-regex, gateway-method, needs_token)
_ROUTES = [
    ("POST", re.compile(r"^/requests/?$"), "handle_submit"),
    ("GET", re.compile(r"^/requests/?$"), "handle_list"),
    ("POST", re.compile(r"^/jobs/lease/?$"), "handle_lease"),
    ("POST", re.compile(r"^/jobs/(?P<job_id>[^/]+)/heartbeat/?$"),
     "handle_job_heartbeat"),
    ("POST", re.compile(r"^/jobs/(?P<job_id>[^/]+)/complete/?$"),
     "handle_job_complete"),
    ("GET", re.compile(r"^/workers/?$"), "handle_workers"),
    ("GET", re.compile(r"^/requests/(?P<request_id>[^/]+)/workflow/?$"),
     "handle_workflow"),
    ("GET", re.compile(r"^/requests/(?P<request_id>[^/]+)/?$"),
     "handle_status"),
    ("GET", re.compile(r"^/collections/(?P<name>.+)/contents/?$"),
     "handle_contents"),
    ("GET", re.compile(r"^/collections/(?P<name>.+?)/?$"),
     "handle_collection"),
    ("GET", re.compile(r"^/stats/?$"), "handle_stats"),
    ("GET", re.compile(r"^/healthz/?$"), "handle_healthz"),
]


def _make_handler(gw: RestGateway):
    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"
        server_version = "idds-rest/1.0"

        # -- plumbing ----------------------------------------------------
        def log_message(self, fmt, *args):  # noqa: A003
            if not gw.quiet:
                BaseHTTPRequestHandler.log_message(self, fmt, *args)

        def _token(self) -> str:
            auth = self.headers.get("Authorization", "")
            if auth.lower().startswith("bearer "):
                return auth[7:].strip()
            return self.headers.get("X-IDDS-Token", "")

        def _drain_body(self) -> None:
            """Consume any unread request body before replying: leaving
            bytes on a keep-alive connection desyncs the next request."""
            if getattr(self, "_body_consumed", False):
                return
            self._body_consumed = True
            length = int(self.headers.get("Content-Length", 0) or 0)
            if length <= 0:
                return
            if length > MAX_BODY_BYTES:
                self.close_connection = True  # cheaper than reading it
                return
            while length > 0:
                chunk = self.rfile.read(min(length, 65536))
                if not chunk:
                    break
                length -= len(chunk)

        def _reply(self, status: int, body: Any) -> None:
            self._drain_body()
            payload = json.dumps(body).encode("utf-8")
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(payload)))
            if self.close_connection:
                self.send_header("Connection", "close")
            self.end_headers()
            self.wfile.write(payload)

        def _dispatch(self, method: str) -> None:
            # Route on the still-quoted path; unquote captured segments in
            # _invoke so %2F inside a collection name survives routing.
            path = urllib.parse.urlsplit(self.path).path
            matched_path = False
            for m, rx, fn_name in _ROUTES:
                match = rx.match(path)
                if match is None:
                    continue
                if m != method:
                    matched_path = True
                    continue
                try:
                    status, body = self._invoke(fn_name, match)
                except AuthError as e:
                    status, body = 401, _err("AuthError", str(e))
                except SchedulerConflict as e:
                    status, body = 409, _err("Conflict", str(e))
                except _NotDistributed as e:
                    status, body = 400, _err("NotDistributed", str(e))
                except Exception as e:  # noqa: BLE001 — envelope, not trace
                    status, body = 500, _err(type(e).__name__, str(e))
                self._reply(status, body)
                return
            if matched_path:
                self._reply(405, _err("MethodNotAllowed",
                                      f"{method} not allowed on {path}"))
            else:
                self._reply(404, _err("NotFound", f"no route for {path}"))

        # handlers that consume the request body (all POST routes)
        _BODY_HANDLERS = frozenset({
            "handle_submit", "handle_lease", "handle_job_heartbeat",
            "handle_job_complete"})

        def _invoke(self, fn_name: str, match) -> Tuple[int, Any]:
            token = self._token()
            if fn_name == "handle_healthz":
                return gw.handle_healthz()
            kwargs = {k: urllib.parse.unquote(v)
                      for k, v in match.groupdict().items()}
            if fn_name in self._BODY_HANDLERS:
                length = int(self.headers.get("Content-Length", 0))
                if length > MAX_BODY_BYTES:
                    self._body_consumed = True
                    self.close_connection = True  # body left unread
                    return 413, _err("PayloadTooLarge",
                                     f"body exceeds {MAX_BODY_BYTES} bytes")
                body = self.rfile.read(length)
                self._body_consumed = True
                return getattr(gw, fn_name)(body=body, token=token,
                                            **kwargs)
            if fn_name == "handle_stats":
                return gw.handle_stats(token)
            if fn_name == "handle_list":
                query = urllib.parse.parse_qs(
                    urllib.parse.urlsplit(self.path).query)
                return gw.handle_list(query, token)
            return getattr(gw, fn_name)(**kwargs, token=token)

        # -- verbs -------------------------------------------------------
        def do_GET(self):  # noqa: N802
            self._dispatch("GET")

        def do_POST(self):  # noqa: N802
            self._dispatch("POST")

        # other verbs get the JSON 405/404 envelope, not stock HTML
        def do_PUT(self):  # noqa: N802
            self._dispatch("PUT")

        def do_DELETE(self):  # noqa: N802
            self._dispatch("DELETE")

        def do_PATCH(self):  # noqa: N802
            self._dispatch("PATCH")

    return Handler


# ---------------------------------------------------------------------------
# CLI entrypoint
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.rest",
        description="Serve the iDDS head service over HTTP.")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8443)
    ap.add_argument("--tokens", default=None,
                    help="comma-separated bearer tokens (omit = auth off)")
    ap.add_argument("--async-wfm", action="store_true",
                    help="run payloads on a WFM worker pool instead of "
                         "inline in the Carrier thread")
    ap.add_argument("--distributed", action="store_true",
                    help="dispatch payloads to pull-based remote workers "
                         "(python -m repro.worker) via the lease "
                         "scheduler instead of executing them in-process")
    ap.add_argument("--lease-ttl", type=float, default=30.0,
                    help="seconds a worker lease lives between "
                         "heartbeats (--distributed)")
    ap.add_argument("--max-workers", type=int, default=8)
    ap.add_argument("--payloads", action="append", default=[],
                    help="importable module that registers payloads "
                         "(repeatable)")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="SQLite file for durable state; requests in "
                         "flight at a crash are recovered on restart "
                         "(omit = in-memory, nothing survives)")
    ap.add_argument("--verbose", action="store_true",
                    help="log each HTTP request")
    args = ap.parse_args(argv)

    for mod in args.payloads:
        importlib.import_module(mod)

    tokens = (set(t for t in args.tokens.split(",") if t)
              if args.tokens else None)
    store = SqliteStore(args.store) if args.store else None
    executor = (DistributedWFM(lease_ttl=args.lease_ttl)
                if args.distributed else None)
    idds = IDDS(sync=not args.async_wfm, max_workers=args.max_workers,
                tokens=tokens, store=store, executor=executor)
    if store is not None:
        counts = idds.recover()
        recovered = {k: v for k, v in counts.items() if v}
        if recovered:
            print(f"idds-rest recovered state from {args.store}: "
                  f"{recovered}", flush=True)
    gw = RestGateway(idds, host=args.host, port=args.port,
                     quiet=not args.verbose)

    # SIGINT/SIGTERM flip an event instead of killing the process
    # mid-write: the daemons drain, the HTTP server closes, and the
    # store is closed cleanly (WAL checkpointed) before exit.
    stop_evt = threading.Event()

    def _on_signal(signum, frame):
        stop_evt.set()

    signal.signal(signal.SIGINT, _on_signal)
    signal.signal(signal.SIGTERM, _on_signal)

    gw.start()
    wfm_mode = ("distributed" if args.distributed else
                "async" if args.async_wfm else "sync")
    print(f"idds-rest serving on {gw.url} "
          f"(auth={'on' if tokens else 'off'}, "
          f"wfm={wfm_mode}, "
          f"store={args.store or 'memory'})", flush=True)
    try:
        stop_evt.wait()
        print("signal received: shutting down", flush=True)
    finally:
        gw.stop()       # HTTP server down, then daemons stopped
        idds.close()    # store closed last, after the final writes
        print("idds-rest stopped (daemons stopped, store closed)",
              flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
