"""Request lifecycle commands (the steering plane).

The paper's iDDS is not submit-and-watch: operators steer running
workflows — abort a bad campaign, suspend one while a storage endpoint
drains, resume it later, retry the transforms that failed.  A command is
a first-class journaled entity (like a request) so steering survives a
head crash: ``IDDS.command()`` journals it *before* announcing it on the
bus, the :class:`~repro.core.daemons.Commander` daemon applies it and
journals the terminal transition, and ``IDDS.recover()`` replays any
command journaled but not yet applied — exactly once, because applying
is idempotent and an applied command is journaled as ``done``.

Actions (all request-scoped):

  abort    cancel the request: non-terminal works and processings turn
           ``cancelled``, outstanding worker leases are revoked (the
           worker observes the fence on its next heartbeat and drops
           the job), and no further dispatch happens.  Terminal.
  suspend  fence the request: pending jobs stop being leased, live
           leases are revoked back to a parked state, and the daemons
           stop creating/submitting processings for it.  Reversible.
  resume   lift a suspension: parked processings are re-submitted and
           fenced jobs become leasable again.
  retry    re-run the request's terminally-failed processings with a
           fresh attempt budget (works leave ``failed``/``subfinished``
           and are finalized again when the re-runs complete).

Command statuses: ``pending`` (journaled, not yet applied) -> ``done``
or ``failed`` (validation failed at apply time; ``error`` says why).
"""
from __future__ import annotations

import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

VALID_COMMAND_ACTIONS = ("abort", "suspend", "resume", "retry")

# request control states (Context.control values; absence means active)
CTRL_SUSPENDED = "suspended"
CTRL_ABORTED = "aborted"


class CommandConflict(Exception):
    """The command cannot apply to the request's current lifecycle state
    (e.g. resume on a request that is not suspended, or any steering of
    an aborted request).  Maps to HTTP 409."""


def _new_command_id() -> str:
    return f"cmd-{uuid.uuid4().hex[:12]}"


@dataclass
class Command:
    """One journaled steering command against a request."""
    request_id: str
    action: str
    workflow_id: str = ""
    command_id: str = field(default_factory=_new_command_id)
    status: str = "pending"          # pending | done | failed
    created_at: float = field(default_factory=time.time)
    processed_at: Optional[float] = None
    error: Optional[str] = None
    # what the apply touched: {"works": n, "processings": n, ...}
    detail: Optional[Dict[str, Any]] = None

    def to_dict(self) -> Dict[str, Any]:
        return {
            "command_id": self.command_id,
            "request_id": self.request_id,
            "workflow_id": self.workflow_id,
            "action": self.action,
            "status": self.status,
            "created_at": self.created_at,
            "processed_at": self.processed_at,
            "error": self.error,
            "detail": self.detail,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Command":
        return cls(
            request_id=d["request_id"],
            action=d["action"],
            workflow_id=d.get("workflow_id", ""),
            command_id=d["command_id"],
            status=d.get("status", "pending"),
            created_at=d.get("created_at", time.time()),
            processed_at=d.get("processed_at"),
            error=d.get("error"),
            detail=d.get("detail"),
        )

    @property
    def pending(self) -> bool:
        return self.status == "pending"
