"""The eight iDDS daemons (paper Fig. 1 + the steering plane) + the
WFM-system boundary.

  Clerk       requests -> Workflow objects
  Marshaller  DG management: Workflow -> Works; condition evaluation
  Commander   lifecycle commands (abort/suspend/resume/retry) -> the
              live object graph (see commands.py)
  Transformer input/output association; Work -> Processing(s); DDM calls
  Carrier     Processing -> WFM submit / poll / retry (job attempts)
  Conductor   output availability -> tracked consumer deliveries,
              journaled as outbox messages (transactional outbox)
  Publisher   outbox drain: fans journaled messages out to their push
              channels (bus / webhook) in batches, store-claimed so any
              head can own cluster fan-out
  Watchdog    cluster coordination: health heartbeats, claim renewal,
              and adoption of workflows whose head died (the paper's
              Health table + clean_locking)

Every daemon exposes ``process_once() -> int`` (number of messages
handled) so the head service can pump deterministically (tests) or spin
daemon threads (production mode).

Multi-head mode: several head processes run these daemons against ONE
store and a store-backed bus (messaging.StorePollingBus).  Every
workflow is owned by exactly one head at a time through the store's
claim table; each daemon claim-gates the messages it consumes and
requeues messages for workflows another live head owns.  With the
default in-process LocalBus the gate degenerates to an always-succeed
claim against the local store, so single-head behavior is unchanged.
"""
from __future__ import annotations

import json
import threading
import time
import urllib.request
import uuid
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from repro.core import messaging as M
from repro.core import payloads as reg
from repro.core.commands import (CTRL_ABORTED, CTRL_SUSPENDED, Command,
                                 CommandConflict)
from repro.core.ddm import DDM
from repro.core.delivery import (UNDELIVERED_STATUSES, Subscription,
                                 backoff_delay, outbox_message)
from repro.core.obs import SLOW_OP_THRESHOLD_S, get_logger
from repro.core.store import InMemoryStore, Store
from repro.core.workflow import (Processing, ProcessingStatus, Work,
                                 WorkStatus, Workflow, _new_id)


# ---------------------------------------------------------------------------
# WFM system boundary (the paper's PanDA)
# ---------------------------------------------------------------------------


class WFMExecutor:
    """Executes Processing payloads. sync=True runs inline at submit
    (deterministic pump); sync=False uses a worker pool ('grid sites').

    ``fault_hook(processing) -> Optional[str]`` injects failures (tests /
    the carousel simulator's 'input not staged yet' failure mode).
    """

    def __init__(self, *, sync: bool = True, max_workers: int = 8,
                 fault_hook: Optional[Callable[[Processing],
                                               Optional[str]]] = None):
        self.sync = sync
        self.fault_hook = fault_hook
        self._pool = (None if sync else
                      ThreadPoolExecutor(max_workers=max_workers,
                                         thread_name_prefix="wfm"))
        self._futures: Dict[str, Future] = {}
        self._lock = threading.RLock()
        self.submitted = 0

    def attach(self, ctx: "Context") -> None:
        """Late-bind the shared Context (store, stats).  The inline
        executor needs nothing from it; ``DistributedWFM`` (scheduler.py)
        wires its lease scheduler to the store here."""

    # -- lifecycle-command hooks (Commander calls these) -----------------
    def fence(self, procs: List[Processing]) -> None:
        """Suspend: stop outstanding execution being handed out.  The
        inline executors have no leases to fence — already-running
        payloads simply finish; only *new* submissions are parked (by
        the Carrier).  ``DistributedWFM`` revokes live worker leases."""

    def release(self, procs: List[Processing]) -> None:
        """Resume: undo ``fence`` for these processings."""

    def cancel(self, procs: List[Processing]) -> None:
        """Abort: forget these processings entirely.  A thread-pool
        payload already running cannot be interrupted, but dropping its
        future means its (stale) outcome is never observed."""
        with self._lock:
            for p in procs:
                self._futures.pop(p.proc_id, None)

    def _execute(self, proc: Processing) -> Processing:
        try:
            if self.fault_hook is not None:
                err = self.fault_hook(proc)
                if err:
                    raise RuntimeError(err)
            fn = reg.get_payload(proc.payload)
            proc.result = fn(dict(proc.params), list(proc.input_files))
            proc.status = ProcessingStatus.FINISHED
        except Exception as e:  # noqa: BLE001 — payload errors become retries
            proc.status = ProcessingStatus.FAILED
            proc.error = f"{type(e).__name__}: {e}"
        return proc

    def submit(self, proc: Processing) -> None:
        with self._lock:
            self.submitted += 1
            proc.status = ProcessingStatus.RUNNING
            if self.sync:
                self._execute(proc)
            else:
                self._futures[proc.proc_id] = self._pool.submit(
                    self._execute, proc)

    def poll(self, proc: Processing) -> Processing:
        if self.sync:
            return proc
        with self._lock:
            fut = self._futures.get(proc.proc_id)
        if fut is not None and fut.done():
            with self._lock:
                self._futures.pop(proc.proc_id, None)
            return fut.result()
        return proc

    def shutdown(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)


# ---------------------------------------------------------------------------
# Shared daemon context
# ---------------------------------------------------------------------------


@dataclass
class Context:
    bus: M.MessageBus
    ddm: DDM
    wfm: WFMExecutor
    # durable catalog: daemons journal every request/work/processing/
    # collection state transition through it (paper §2's database-backed
    # catalogs); IDDS.recover() replays it after a crash
    store: Store = field(default_factory=InMemoryStore)
    workflows: Dict[str, Workflow] = field(default_factory=dict)
    works: Dict[str, Tuple[str, Work]] = field(default_factory=dict)
    processings: Dict[str, Processing] = field(default_factory=dict)
    # request catalog mirror (request_id -> info dict) + the reverse map
    # the Marshaller uses to write request status transitions through to
    # the store at the moment they happen (event-driven, so GET /requests
    # filters stay truthful without rescanning every request per call)
    requests: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    request_of: Dict[str, str] = field(default_factory=dict)
    # workflow_ids whose initial works were instantiated (wf.start()):
    # makes the Marshaller's T_NEW_WORKFLOWS handling idempotent under
    # duplicate delivery and post-recovery replays
    started_workflows: Set[str] = field(default_factory=set)
    # steering plane: workflow_id -> "suspended" | "aborted" (absence
    # means active — daemons gate dispatch/submission on this), plus the
    # command registry the Commander applies from (command_id -> Command)
    # and its per-request index (status polls tally a request's commands
    # on every poll — that must not scan every command ever submitted)
    control: Dict[str, str] = field(default_factory=dict)
    commands: Dict[str, Command] = field(default_factory=dict)
    commands_by_request: Dict[str, List[Command]] = field(
        default_factory=dict)

    def register_command(self, cmd: Command) -> None:
        """Index a new command (caller holds ``lock``)."""
        self.commands[cmd.command_id] = cmd
        self.commands_by_request.setdefault(cmd.request_id,
                                            []).append(cmd)
    # delivery plane: consumer subscriptions the Conductor matches
    # output availability against (sub_id -> Subscription); mutated by
    # REST threads under ``lock``, journaled through ``store``
    subscriptions: Dict[str, Subscription] = field(default_factory=dict)
    stats: Dict[str, int] = field(default_factory=dict)
    # workflow_id -> #work-termination events published but not yet
    # condition-evaluated by the Marshaller.  While > 0 the workflow may
    # still grow new Works, so it must not be reported "finished" even if
    # every existing Work is terminal (threaded-mode status race).
    inflight: Dict[str, int] = field(default_factory=dict)
    # multi-head ownership plane (the paper's TransformLocking): this
    # head's stable identity, the wall-clock claim TTL, and a local
    # cache of the workflow claims this head believes it holds
    # (workflow_id -> claimed_until).  ``try_own`` hits the store only
    # once a cached claim has burned half its TTL, so the single-head
    # fast path costs one dict lookup per gated message.
    head_id: str = field(
        default_factory=lambda: f"head-{uuid.uuid4().hex[:8]}")
    claim_ttl: float = 5.0
    claimed: Dict[str, float] = field(default_factory=dict)
    lock: threading.RLock = field(default_factory=threading.RLock)
    # telemetry plane (obs.py), wired by IDDS: the head's metrics
    # registry, the lifecycle-event tracer, the scheduler trace hook,
    # and the workflow_id -> trace_id map that lets daemons stamp bus
    # publishes / trace events without threading ids through each call
    metrics: Optional[Any] = None
    tracer: Optional[Any] = None
    sched_event: Optional[Callable[..., None]] = None
    trace_ids: Dict[str, str] = field(default_factory=dict)

    def bump(self, key: str, n: int = 1) -> None:
        with self.lock:
            self.stats[key] = self.stats.get(key, 0) + n

    def trace(self, event: str, **kw: Any) -> None:
        """Emit a lifecycle trace event (no-op without a tracer)."""
        if self.tracer is not None:
            self.tracer.emit(event, **kw)

    def trace_id_of(self, workflow_id: Optional[str]) -> Optional[str]:
        if workflow_id is None:
            return None
        with self.lock:
            return self.trace_ids.get(workflow_id)

    def inflight_add(self, workflow_id: str, n: int) -> None:
        with self.lock:
            self.inflight[workflow_id] = self.inflight.get(workflow_id, 0) + n

    def quiescent(self, workflow_id: str) -> bool:
        with self.lock:
            return self.inflight.get(workflow_id, 0) == 0

    def try_own(self, workflow_id: str) -> bool:
        """Claim (or confirm) this head's ownership of a workflow.

        The store's compare-and-claim is authoritative; the cache only
        short-circuits while a claim has more than half its TTL left,
        so a head that lost its claim (it stopped renewing for > TTL)
        re-discovers that within half a TTL, before acting on it."""
        now = time.time()
        with self.lock:
            if self.claimed.get(workflow_id, 0.0) - now \
                    > self.claim_ttl / 2:
                return True
        ok = self.store.try_claim("workflow", workflow_id, self.head_id,
                                  self.claim_ttl, now=now)
        with self.lock:
            if ok:
                self.claimed[workflow_id] = now + self.claim_ttl
            else:
                self.claimed.pop(workflow_id, None)
        return ok

    def disown(self, workflow_id: str) -> None:
        """Release a workflow claim (its request turned terminal), so
        cluster claim counts reflect live work only."""
        with self.lock:
            self.claimed.pop(workflow_id, None)
        self.store.release_claim("workflow", workflow_id, self.head_id)


class Daemon:
    name = "daemon"
    # bus topics this daemon consumes: an idle thread blocks on the bus
    # condition for these instead of sleep-and-poll, so a publish wakes
    # it immediately and idle loops burn far fewer wakeups
    topics: Tuple[str, ...] = ()

    def __init__(self, ctx: Context):
        self.ctx = ctx
        self.log = get_logger(f"daemon.{self.name}")

    def process_once(self) -> int:
        raise NotImplementedError

    def _owned(self, m: M.Message,
               workflow_id: Optional[str]) -> bool:
        """Claim-gate one consumed message: True means this head owns
        the workflow AND has it hydrated, so the message is processed
        here.  Otherwise the message is requeued — either another live
        head owns the workflow, or ownership just landed here and the
        Watchdog's adoption sweep still has to hydrate the object graph
        from the store.  ``workflow_id`` None (a producer with no
        routing info, e.g. an external T_OUTPUT_AVAILABLE) passes."""
        if workflow_id is None:
            return True
        if (self.ctx.try_own(workflow_id)
                and workflow_id in self.ctx.workflows):
            return True
        self.ctx.bus.requeue(m)
        return False

    def _idle_wait(self, interval: float) -> None:
        if self.topics:
            self.ctx.bus.wait_any(self.topics, timeout=interval)
        else:
            time.sleep(interval)

    def run_forever(self, stop: threading.Event, interval: float = 0.05):
        m = self.ctx.metrics
        loop_h = (m.histogram("daemon_loop_seconds",
                              "one process_once round",
                              labels=("daemon",)).labels(daemon=self.name)
                  if m is not None else None)
        msgs_c = (m.counter("daemon_messages_total", "messages handled",
                            labels=("daemon",)).labels(daemon=self.name)
                  if m is not None else None)
        while not stop.is_set():
            t0 = time.monotonic()
            try:
                n = self.process_once()
            except Exception:  # pragma: no cover - daemon resilience
                self.log.exception("daemon round failed")
                n = 0
            dt = time.monotonic() - t0
            if loop_h is not None:
                loop_h.observe(dt)
                if n:
                    msgs_c.inc(n)
            if dt > SLOW_OP_THRESHOLD_S:
                self.log.warning(
                    "slow daemon round: %.3fs (%d messages)", dt, n,
                    extra={"daemon": self.name,
                           "duration_s": round(dt, 3)})
            if n == 0:
                self._idle_wait(interval)


# ---------------------------------------------------------------------------
# Clerk: requests -> Workflow objects
# ---------------------------------------------------------------------------


class Clerk(Daemon):
    name = "clerk"
    topics = (M.T_NEW_REQUESTS,)

    def process_once(self) -> int:
        n = 0
        for m in self.ctx.bus.poll(M.T_NEW_REQUESTS):
            wf = Workflow.from_json(m.body["workflow"])
            # claim BEFORE instantiating: in a cluster only the claiming
            # head may start the workflow; a loser requeues for whoever
            # owns it.  The message carries the full workflow, so any
            # head can clerk it — no hydration wait here.
            if not self.ctx.try_own(wf.workflow_id):
                self.ctx.bus.requeue(m)
                continue
            n += 1
            rid = m.body.get("request_id")
            tid = m.trace_id
            with self.ctx.lock:
                # keep the live object on duplicate delivery (a client
                # resubmit after recovery): its works are already running
                if wf.workflow_id not in self.ctx.workflows:
                    self.ctx.workflows[wf.workflow_id] = wf
                if rid:
                    self.ctx.request_of[wf.workflow_id] = rid
                if tid:
                    self.ctx.trace_ids.setdefault(wf.workflow_id, tid)
            if rid is not None and rid not in self.ctx.requests:
                # submitted through ANOTHER head: its REST layer seeded
                # its own request mirror; this head must learn the
                # catalog row or status write-through would skip it
                info = self.ctx.store.get_request(rid)
                if info is not None:
                    with self.ctx.lock:
                        self.ctx.requests.setdefault(rid, dict(info))
                        if not tid and info.get("trace_id"):
                            tid = info["trace_id"]
                            self.ctx.trace_ids.setdefault(
                                wf.workflow_id, tid)
            self.ctx.bump("requests")
            self.ctx.trace("workflow_started", request_id=rid,
                           trace_id=tid)
            self.ctx.bus.publish(M.T_NEW_WORKFLOWS, {
                "workflow_id": wf.workflow_id,
                "request_id": rid,
            }, trace_id=tid)
        return n


# ---------------------------------------------------------------------------
# Marshaller: DG management (Workflow -> Works, condition evaluation)
# ---------------------------------------------------------------------------


class Marshaller(Daemon):
    name = "marshaller"
    topics = (M.T_NEW_WORKFLOWS, M.T_WORK_DONE)

    def _emit(self, wf: Workflow, works: List[Work],
              journal_with: Optional[List[Work]] = None) -> None:
        """Register, journal, and announce freshly instantiated works.

        ``journal_with`` rides in the same store transaction: the
        Marshaller persists a condition-evaluated trigger Work together
        with its successors, so a crash can never record the evaluation
        without the works it spawned (or vice versa).
        """
        with self.ctx.lock:
            for w in works:
                self.ctx.works[w.work_id] = (wf.workflow_id, w)
            dicts = [w.to_dict() for w in (journal_with or []) + works]
        if dicts:
            self.ctx.store.save_works(wf.workflow_id, dicts)
        if works:
            self.ctx.bump("works_created", len(works))
        tid = self.ctx.trace_id_of(wf.workflow_id)
        for w in works:
            self.ctx.bus.publish(M.T_NEW_WORKS, {
                "workflow_id": wf.workflow_id, "work_id": w.work_id},
                trace_id=tid)

    def _refresh_request(self, wf: Workflow) -> None:
        """Write the owning request's status transition through to the
        catalog at the event that caused it — running once works exist,
        finished once all works are terminal and no evaluation is
        pending — so listings filter on fresh rows without rescanning
        every request per query."""
        rid = self.ctx.request_of.get(wf.workflow_id)
        if rid is None:
            return
        with self.ctx.lock:
            if self.ctx.control.get(wf.workflow_id):
                return  # suspended/aborted: the Commander owns status
            info = self.ctx.requests.get(rid)
            if info is None:
                return
            done = wf.finished and self.ctx.quiescent(wf.workflow_id)
            status = "finished" if done else "running"
            if info.get("status") == status:
                return
            info["status"] = status
            snapshot = dict(info)
        self.ctx.store.save_request(snapshot)
        if status == "finished":
            self.ctx.disown(wf.workflow_id)

    def process_once(self) -> int:
        # wf.works mutations happen under ctx.lock so status polls can
        # snapshot consistently; publishes stay OUTSIDE the lock (bus
        # subscribers like DAGScheduler take ctx.lock under the bus lock,
        # so publishing while holding ctx.lock could deadlock).
        n = 0
        for m in self.ctx.bus.poll(M.T_NEW_WORKFLOWS):
            if not self._owned(m, m.body.get("workflow_id")):
                continue
            n += 1
            try:
                wf = self.ctx.workflows[m.body["workflow_id"]]
                with self.ctx.lock:
                    if wf.workflow_id in self.ctx.started_workflows:
                        continue  # duplicate delivery / recovery replay
                    if self.ctx.control.get(wf.workflow_id) \
                            == CTRL_ABORTED:
                        # aborted before the DG ever started: never start
                        self.ctx.started_workflows.add(wf.workflow_id)
                        continue
                    self.ctx.started_workflows.add(wf.workflow_id)
                    new_works = wf.start()
                self._emit(wf, new_works)
                self._refresh_request(wf)
            except Exception:  # one bad workflow must not drop the batch
                self.ctx.bump("marshaller_errors")
                self.log.exception("workflow start failed for %s",
                                   m.body.get("workflow_id"))
        for m in self.ctx.bus.poll(M.T_WORK_DONE):
            ent = self.ctx.works.get(m.body["work_id"])
            wf_hint = m.body.get("workflow_id") or (ent and ent[0])
            if not self._owned(m, wf_hint):
                continue
            if ent is None:
                # ownership landed here before the adoption sweep
                # hydrated the work: retry once the graph exists
                self.ctx.bus.requeue(m)
                continue
            n += 1
            # per-message isolation: poll() already drained the queue, so
            # an exception that escaped this loop would silently discard
            # every later message in the batch (their workflows would
            # report "running" forever)
            try:
                wf_id, work = ent
                wf = self.ctx.workflows[wf_id]
                with self.ctx.lock:
                    if work.condition_evaluated:
                        # duplicate delivery: the store bus can carry
                        # both the dead head's original announcement and
                        # this head's adoption replay of the same event
                        continue
                    # decrement in the same locked section that
                    # instantiates the successors: a poll never sees
                    # quiescent + all-works terminal while successors are
                    # pending.  finally: a raising predicate/binder must
                    # not wedge the counter.
                    try:
                        if self.ctx.control.get(wf_id) == CTRL_ABORTED:
                            # a straggler finishing after an abort must
                            # not spawn successors of a dead request
                            new_works = []
                        else:
                            new_works = wf.on_terminated(work)
                        work.condition_evaluated = True
                    finally:
                        self.ctx.inflight_add(wf_id, -1)
                self._emit(wf, new_works, journal_with=[work])
                self._refresh_request(wf)
            except Exception:
                self.ctx.bump("marshaller_errors")
                self.log.exception("condition evaluation failed for "
                                   "work %s", m.body.get("work_id"))
        return n


# ---------------------------------------------------------------------------
# Transformer: Work -> Processing(s), input/output association
# ---------------------------------------------------------------------------


class Transformer(Daemon):
    """Creates Processings at the Work's granularity.

    fine   — one Processing per available input file, created incrementally
             as DDM announces availability (paper §3.1: 'input data is
             incrementally processed based on detailed knowledge of the
             status of input data').
    coarse — one Processing once the ENTIRE input collection is available
             (the pre-iDDS baseline the paper improves on).
    """
    name = "transformer"
    topics = (M.T_NEW_WORKS, M.T_COLLECTION_UPDATED, M.T_PROCESSING_DONE,
              M.T_CMD_TRANSFORMER)

    def __init__(self, ctx: Context):
        super().__init__(ctx)
        self._pending: Dict[str, Work] = {}          # works awaiting inputs
        self._dispatched: Dict[str, set] = {}        # work_id -> file names
        self._open_procs: Dict[str, int] = {}        # work_id -> #unfinished
        self._work_procs: Dict[str, List[Processing]] = {}  # work -> procs
        # last journaled (available, processed, status) per file per
        # collection: journaling writes only the rows that changed, not
        # a full snapshot per event (O(changes), not O(files^2))
        self._coll_state: Dict[str, Dict[str, Tuple[bool, bool, str]]] = {}

    # -- helpers ----------------------------------------------------------
    def _make_processing(self, work: Work, files: List[str]) -> Processing:
        wf_id, _ = self.ctx.works[work.work_id]
        proc = Processing(
            proc_id=_new_id("proc"),
            work_id=work.work_id,
            payload=work.payload,
            params=dict(work.params),
            input_files=list(files),
            output_files=[f"{work.output_collection or work.work_id}/out-"
                          f"{len(self._dispatched.get(work.work_id, ()))}"],
            max_attempts=work.max_attempts,
        )
        with self.ctx.lock:
            self.ctx.processings[proc.proc_id] = proc
        self._work_procs.setdefault(work.work_id, []).append(proc)
        self._open_procs[work.work_id] = (
            self._open_procs.get(work.work_id, 0) + 1)
        self.ctx.store.save_processing(proc.to_dict())
        self.ctx.bump("processings_created")
        self.ctx.bus.publish(M.T_NEW_PROCESSINGS,
                             {"proc_id": proc.proc_id,
                              "workflow_id": wf_id},
                             trace_id=self.ctx.trace_id_of(wf_id))
        return proc

    def _try_dispatch(self, work: Work) -> int:
        """Create whatever Processings the current input state allows;
        returns how many were created (callers journal on > 0)."""
        wf_id, _ = self.ctx.works[work.work_id]
        if self.ctx.control.get(wf_id):
            return 0  # suspended/aborted: no new processings
        if work.input_collection is None:
            # truthiness, not key presence: recovery may have seeded an
            # empty dispatched-set for a work that never got its
            # Processing (e.g. suspended before dispatch)
            if not self._dispatched.get(work.work_id):
                self._dispatched[work.work_id] = {"__virtual__"}
                work.status = WorkStatus.TRANSFORMING
                self._make_processing(work, [])
                return 1
            return 0

        coll = self.ctx.ddm.get_collection(work.input_collection)
        done = self._dispatched.setdefault(work.work_id, set())
        if work.granularity == "coarse":
            if done:
                return 0
            # dispatch once every file is terminal (available or failed
            # staging) — a terminally-failed shard must not make the
            # baseline wait forever; the survivors are processed and the
            # skips surface as fails in _finalize (subfinished)
            if any(not f.available and f.status != "failed"
                   for f in coll.files):
                return 0
            ready = [f.name for f in coll.files if f.available]
            if coll.files and not ready:
                return 0  # every shard failed: _work_complete finalizes
            done.add("__all__")
            work.status = WorkStatus.TRANSFORMING
            self._make_processing(work, ready)
            return 1
        # fine granularity: one Processing per newly-available file
        created = 0
        for f in coll.files:
            if f.available and f.name not in done:
                done.add(f.name)
                work.status = WorkStatus.TRANSFORMING
                self._make_processing(work, [f.name])
                created += 1
        return created

    def _journal_dispatch(self, work: Work) -> None:
        """Persist a work's post-dispatch state + its input collection
        (availability drives re-dispatch decisions after recovery)."""
        wf_id, _ = self.ctx.works[work.work_id]
        with self.ctx.lock:
            d = work.to_dict()
        self.ctx.store.save_work(wf_id, d)
        if work.input_collection is not None:
            self._journal_collection(work.input_collection)

    def _journal_collection(self, name: str) -> None:
        """Journal a collection incrementally: full snapshot on first
        sight, then only the content rows whose availability/processed
        flags changed since the last journal."""
        coll = self.ctx.ddm.get_collection(name)
        seen = self._coll_state.get(name)
        if seen is None:
            self.ctx.store.save_collection(coll.to_dict())
            self._coll_state[name] = {
                f.name: (f.available, f.processed, f.status)
                for f in coll.files}
            return
        changed = [f for f in coll.files
                   if seen.get(f.name) != (f.available, f.processed,
                                           f.status)]
        if changed:
            self.ctx.store.save_contents(
                name, [f.to_dict() for f in changed])
            for f in changed:
                seen[f.name] = (f.available, f.processed, f.status)

    def _work_complete(self, work: Work) -> bool:
        if self._open_procs.get(work.work_id, 0) > 0:
            return False
        if work.input_collection is None:
            return bool(self._dispatched.get(work.work_id))
        coll = self.ctx.ddm.get_collection(work.input_collection)
        done = self._dispatched.get(work.work_id, set())
        if work.granularity == "coarse":
            if done:
                return True
            # every shard failed staging: nothing will ever dispatch —
            # complete with zero procs; _finalize counts the fails
            return bool(coll.files) and all(f.status == "failed"
                                            for f in coll.files)
        # fine: every input dispatched, EXCEPT contents that failed
        # staging terminally — those can never become available, and
        # waiting on them would wedge the work (they surface as fails
        # in _finalize instead)
        return all(f.name in done for f in coll.files
                   if f.status != "failed")

    def _finalize(self, work: Work) -> None:
        wf_id, _ = self.ctx.works[work.work_id]
        procs = self._work_procs.pop(work.work_id, [])
        fails = sum(1 for p in procs
                    if p.status in (ProcessingStatus.FAILED,
                                    ProcessingStatus.CANCELLED))
        if work.input_collection is not None:
            # inputs that failed staging terminally never got a
            # Processing; they still count against a clean FINISHED
            done = self._dispatched.get(work.work_id, set())
            coll = self.ctx.ddm.get_collection(work.input_collection)
            fails += sum(1 for f in coll.files
                         if f.status == "failed" and f.name not in done)
        # a work re-finalizing after a `retry` command already had its
        # conditions evaluated — successors from the original evaluation
        # exist, so re-announcing T_WORK_DONE would double-spawn them
        announce = not work.condition_evaluated
        with self.ctx.lock:
            # count the termination event atomically with the work turning
            # terminal, so no status poll can observe "all works terminal"
            # with the condition evaluation still queued
            if announce:
                self.ctx.inflight_add(wf_id, 1)
            work.status = (WorkStatus.FINISHED if fails == 0 else
                           WorkStatus.SUBFINISHED)
            work.terminated_at = time.time()
            # merge processing results: last wins per key; keep the list too
            merged: Dict[str, Any] = {}
            for p in sorted((p for p in procs if p.result),
                            key=lambda p: p.proc_id):
                merged.update(p.result)
                work.results.append(p.result)
            work.result = merged or work.result
            d = work.to_dict()
        self._pending.pop(work.work_id, None)
        # journal the terminal state (condition_evaluated still False)
        # BEFORE announcing it: if we crash in between, recovery sees a
        # terminal, unevaluated work and replays the T_WORK_DONE event
        self.ctx.store.save_work(wf_id, d)
        self.ctx.bump("works_finished")
        tid = self.ctx.trace_id_of(wf_id)
        self.ctx.trace("work_done",
                       request_id=self.ctx.request_of.get(wf_id),
                       trace_id=tid, entity=work.work_id,
                       data={"status": getattr(work.status, "value",
                                               str(work.status))})
        if announce:
            self.ctx.bus.publish(M.T_WORK_DONE,
                                 {"work_id": work.work_id,
                                  "workflow_id": wf_id}, trace_id=tid)

    # -- steering (Commander -> Transformer) -------------------------------
    def _handle_control(self, m: M.Message) -> None:
        action = m.body["action"]
        wf_id = m.body["workflow_id"]
        if action == "abort":
            # the Commander already cancelled the works; drop the
            # dispatch bookkeeping so nothing re-activates them
            for wid in [w.work_id for w in self._pending.values()
                        if self.ctx.works[w.work_id][0] == wf_id]:
                self._pending.pop(wid, None)
                self._dispatched.pop(wid, None)
                self._open_procs.pop(wid, None)
                self._work_procs.pop(wid, None)
        elif action == "resume":
            # re-dispatch whatever each suspended work's inputs allow now
            for work in list(self._pending.values()):
                if self.ctx.works[work.work_id][0] != wf_id:
                    continue
                if self._try_dispatch(work):
                    self._journal_dispatch(work)
                if (self._work_complete(work)
                        and not work.status.terminated):
                    self._finalize(work)
        elif action == "retry":
            # the Commander reset the failed processings to NEW and the
            # works to TRANSFORMING; re-own them and re-announce the
            # fresh attempts (this daemon owns dispatch bookkeeping)
            for wid in m.body.get("work_ids", []):
                _, work = self.ctx.works[wid]
                procs = [p for p in self.ctx.processings.values()
                         if p.work_id == wid]
                self._pending[wid] = work
                self._work_procs[wid] = procs
                self._open_procs[wid] = sum(
                    1 for p in procs if not p.terminal)
                # re-seed the dispatched-inputs set exactly like crash
                # recovery does: after a head restart nothing restored
                # it for this (then-terminal) work, and _work_complete
                # requires it to be truthy to ever finalize again
                done = self._dispatched.setdefault(wid, set())
                for p in procs:
                    if work.input_collection is None:
                        done.add("__virtual__")
                    elif work.granularity == "coarse":
                        done.add("__all__")
                    else:
                        done.update(p.input_files)
                for p in procs:
                    if p.status == ProcessingStatus.NEW:
                        self.ctx.bus.publish(M.T_NEW_PROCESSINGS,
                                             {"proc_id": p.proc_id,
                                              "workflow_id": wf_id})

    # -- main loop ---------------------------------------------------------
    def process_once(self) -> int:
        n = 0
        for m in self.ctx.bus.poll(M.T_CMD_TRANSFORMER):
            if not self._owned(m, m.body.get("workflow_id")):
                continue
            n += 1
            self._handle_control(m)
        for m in self.ctx.bus.poll(M.T_NEW_WORKS):
            if not self._owned(m, m.body.get("workflow_id")):
                continue
            ent = self.ctx.works.get(m.body["work_id"])
            if ent is None:
                self.ctx.bus.requeue(m)  # owned but not hydrated yet
                continue
            n += 1
            wf_id, work = ent
            if work.status.terminated:
                continue  # cancelled by an abort before activation
            work.status = WorkStatus.ACTIVATED
            self.ctx.trace("work_transforming",
                           request_id=self.ctx.request_of.get(wf_id),
                           trace_id=m.trace_id
                           or self.ctx.trace_id_of(wf_id),
                           entity=work.work_id)
            self._pending[work.work_id] = work
            self._try_dispatch(work)
            self._journal_dispatch(work)

        # DDM announced new file availability (or a terminal staging
        # failure) -> incremental dispatch + completion re-check: a work
        # whose last missing input just failed staging must finalize
        # (subfinished) instead of waiting forever
        updated = {m.body.get("collection")
                   for m in self.ctx.bus.poll(M.T_COLLECTION_UPDATED)}
        if updated:
            n += len(updated)
        for work in list(self._pending.values()):
            if work.input_collection in updated or updated == {None}:
                if self._try_dispatch(work):
                    self._journal_dispatch(work)
                if (self._work_complete(work)
                        and not work.status.terminated):
                    self._journal_dispatch(work)
                    self._finalize(work)

        for m in self.ctx.bus.poll(M.T_PROCESSING_DONE):
            proc = self.ctx.processings.get(m.body["proc_id"])
            wf_hint = m.body.get("workflow_id") or (
                proc and self.ctx.works[proc.work_id][0])
            if not self._owned(m, wf_hint):
                continue
            if proc is None:
                self.ctx.bus.requeue(m)  # owned but not hydrated yet
                continue
            n += 1
            _, work = self.ctx.works[proc.work_id]
            self._open_procs[work.work_id] = max(
                0, self._open_procs.get(work.work_id, 1) - 1)
            if proc.status == ProcessingStatus.FINISHED:
                if work.input_collection is not None:
                    for fname in proc.input_files:
                        try:
                            self.ctx.ddm.mark_processed(
                                work.input_collection, fname)
                        except KeyError:
                            pass
                    self._journal_collection(work.input_collection)
                for out in proc.output_files:
                    wf_id = self.ctx.works[work.work_id][0]
                    self.ctx.bus.publish(M.T_OUTPUT_AVAILABLE, {
                        "work_id": work.work_id,
                        "workflow_id": wf_id,
                        "collection": work.output_collection,
                        "file": out,
                        "result": proc.result,
                    }, trace_id=m.trace_id
                        or self.ctx.trace_id_of(wf_id))
            if self._work_complete(work) and not work.status.terminated:
                # terminated guard: a work cancelled by an abort command
                # must not be resurrected by a late processing outcome
                self._finalize(work)

        # periodic re-scan for coarse works whose inputs completed silently
        for work in list(self._pending.values()):
            if work.status == WorkStatus.ACTIVATED:
                if self._try_dispatch(work):
                    self._journal_dispatch(work)
                if (self._work_complete(work)
                        and not work.status.terminated):
                    self._finalize(work)
        return n

    # -- crash recovery ----------------------------------------------------
    def restore(self, work: Work, procs: List[Processing]) -> None:
        """Rebuild the dispatch bookkeeping for a recovered non-terminal
        work (IDDS.recover): which inputs were already dispatched (from
        its journaled Processings — so no file is processed twice), how
        many of them are still open, and whether the work can already be
        finalized (every proc finished, but the done-events died with
        the old process)."""
        if work.work_id in self._pending:
            return  # idempotent: second recover() must not reset state
        if work.status == WorkStatus.NEW:
            # the T_NEW_WORKS announcement died with the old process
            work.status = WorkStatus.ACTIVATED
        self._pending[work.work_id] = work
        done = self._dispatched.setdefault(work.work_id, set())
        for p in procs:
            if work.input_collection is None:
                done.add("__virtual__")
            elif work.granularity == "coarse":
                done.add("__all__")
            else:
                done.update(p.input_files)
        self._work_procs[work.work_id] = list(procs)
        # non-terminal includes FAILED-with-retries: recover() requeues
        # those, so they are still open from this work's point of view
        self._open_procs[work.work_id] = sum(
            1 for p in procs if not p.terminal)
        for p in procs:
            # a finished proc whose done-event was lost still owes its
            # processed-marks (idempotent on the DDM side)
            if (p.status == ProcessingStatus.FINISHED
                    and work.input_collection is not None):
                for fname in p.input_files:
                    try:
                        self.ctx.ddm.mark_processed(
                            work.input_collection, fname)
                    except KeyError:
                        pass
        if self._work_complete(work):
            self._finalize(work)


# ---------------------------------------------------------------------------
# Carrier: submit to WFM, poll, retry (the paper's job attempts)
# ---------------------------------------------------------------------------


class Carrier(Daemon):
    name = "carrier"
    topics = (M.T_NEW_PROCESSINGS, M.T_CMD_CARRIER)

    def __init__(self, ctx: Context):
        super().__init__(ctx)
        self._running: Dict[str, Processing] = {}
        # wf_id -> {proc_id: Processing} announced while the request was
        # suspended: submitted on resume, dropped on abort
        self._parked: Dict[str, Dict[str, Processing]] = {}

    def _idle_wait(self, interval: float) -> None:
        if self._running:
            # outcomes arrive via WFM polling (worker pool futures or the
            # lease scheduler), not the bus: keep the poll loop ticking
            time.sleep(0.01)
        else:
            super()._idle_wait(interval)

    def _submit(self, proc: Processing) -> None:
        self.ctx.bump("job_attempts")
        wf_id = self._wf_of(proc)
        self.ctx.trace("processing_submitted",
                       request_id=self.ctx.request_of.get(wf_id),
                       trace_id=self.ctx.trace_id_of(wf_id),
                       entity=proc.proc_id,
                       data={"attempt": proc.attempt})
        self.ctx.wfm.submit(proc)
        self._running[proc.proc_id] = proc
        # sync WFM executes inline, so this records the final status;
        # async records RUNNING and the poll loop journals the outcome
        self.ctx.store.save_processing(proc.to_dict())

    def _wf_of(self, proc: Processing) -> str:
        return self.ctx.works[proc.work_id][0]

    def process_once(self) -> int:
        n = 0
        for m in self.ctx.bus.poll(M.T_CMD_CARRIER):
            if not self._owned(m, m.body.get("workflow_id")):
                continue
            n += 1
            wf_id, action = m.body["workflow_id"], m.body["action"]
            if action == "resume":
                for proc in self._parked.pop(wf_id, {}).values():
                    self._submit(proc)
            elif action == "abort":
                self._parked.pop(wf_id, None)
                for pid in [pid for pid, p in self._running.items()
                            if self._wf_of(p) == wf_id]:
                    del self._running[pid]
        for m in self.ctx.bus.poll(M.T_NEW_PROCESSINGS):
            proc = self.ctx.processings.get(m.body["proc_id"])
            wf_hint = m.body.get("workflow_id") or (
                proc and self._wf_of(proc))
            if not self._owned(m, wf_hint):
                continue
            if proc is None:
                self.ctx.bus.requeue(m)  # owned but not hydrated yet
                continue
            if (proc.proc_id in self._running
                    or proc.status != ProcessingStatus.NEW):
                # duplicate delivery: every announcement is published
                # with the processing at NEW, so anything else means a
                # dead head's original message arrived after this
                # head's adoption replay already (re)submitted it
                n += 1
                continue
            n += 1
            ctrl = self.ctx.control.get(self._wf_of(proc))
            if ctrl == CTRL_ABORTED:
                continue  # cancelled by command; nothing to run
            if ctrl == CTRL_SUSPENDED:
                # park instead of submitting; resume re-announces
                self._parked.setdefault(
                    self._wf_of(proc), {})[proc.proc_id] = proc
                continue
            self._submit(proc)

        for proc in list(self._running.values()):
            if (proc.status == ProcessingStatus.CANCELLED
                    or self.ctx.control.get(self._wf_of(proc))
                    == CTRL_ABORTED):
                # aborted mid-flight: whatever the executor eventually
                # reports is stale — drop it without a done-event.  The
                # control check also covers the async-pool race where a
                # still-running payload thread overwrites the CANCELLED
                # status on the shared Processing after the abort.
                n += 1
                del self._running[proc.proc_id]
                continue
            proc = self.ctx.wfm.poll(proc)
            if proc.status == ProcessingStatus.FINISHED:
                n += 1
                del self._running[proc.proc_id]
                if not self.ctx.wfm.sync:  # sync journaled at submit
                    self.ctx.store.save_processing(proc.to_dict())
                self.ctx.bump("processings_finished")
                self._trace_done(proc, failed=False)
                self.ctx.bus.publish(
                    M.T_PROCESSING_DONE, {"proc_id": proc.proc_id},
                    trace_id=self.ctx.trace_id_of(self._wf_of(proc)))
            elif proc.status == ProcessingStatus.FAILED:
                n += 1
                if proc.attempt < proc.max_attempts:
                    proc.attempt += 1
                    proc.error = None
                    self.ctx.bump("job_retries")
                    self._submit(proc)  # re-submission = another attempt
                else:
                    del self._running[proc.proc_id]
                    if not self.ctx.wfm.sync:
                        self.ctx.store.save_processing(proc.to_dict())
                    self.ctx.bump("processings_failed")
                    self.log.warning(
                        "processing %s failed terminally after %d "
                        "attempts: %s", proc.proc_id, proc.attempt,
                        proc.error)
                    self._trace_done(proc, failed=True)
                    self.ctx.bus.publish(
                        M.T_PROCESSING_DONE, {"proc_id": proc.proc_id},
                        trace_id=self.ctx.trace_id_of(self._wf_of(proc)))
        return n

    def _trace_done(self, proc: Processing, *, failed: bool) -> None:
        wf_id = self._wf_of(proc)
        self.ctx.trace("processing_done",
                       request_id=self.ctx.request_of.get(wf_id),
                       trace_id=self.ctx.trace_id_of(wf_id),
                       entity=proc.proc_id,
                       data={"failed": failed,
                             "attempt": proc.attempt})


# ---------------------------------------------------------------------------
# Conductor: output availability -> consumer notifications
# ---------------------------------------------------------------------------


class Conductor(Daemon):
    """The delivery daemon: turns per-file output availability into
    tracked consumer deliveries.

    For every ``T_OUTPUT_AVAILABLE`` it (1) registers the output content
    in the DDM, (2) broadcasts the legacy ``T_CONSUMER_NOTIFY`` for
    in-process listeners, and (3) matches the content against the
    registered :class:`~repro.core.delivery.Subscription` set, creating
    one :class:`~repro.core.delivery.Delivery` per matching
    subscription.  Each created delivery journals an outbox message row
    IN THE SAME ``save_many`` batch as the content row and the
    subscription snapshot (the transactional outbox): a crash can never
    persist the delivery state without its notification or vice versa.
    The Publisher daemon drains the outbox and performs the actual
    channel fan-out.

    Deliveries left un-acked are re-notified on a full-jitter
    exponential backoff schedule (base ``retry_interval``) up to
    ``max_notify_attempts`` total notifications, then marked failed —
    every transition journaled through the store, so a head crash loses
    no delivery state (a recovered ``notified`` delivery is simply
    re-notified).

    With the intelligence plane attached the Conductor also runs the
    service-level hedging pass: it drains each stager's landed staging
    latencies into the HistoryBook and re-submits in-flight files older
    than ``hedge_headroom`` × the learned p95 — the service's history
    replacing the stager-local ``hedge_factor`` guess.
    """
    name = "conductor"
    topics = (M.T_OUTPUT_AVAILABLE,)
    retry_interval = 2.0       # re-notify backoff base (full jitter)
    max_notify_attempts = 5    # total notifications before failure

    def __init__(self, ctx: Context):
        super().__init__(ctx)
        # delivery_id -> monotonic next-retry time.  Absent for a
        # delivery recovered from the store: its original notification
        # died with the old head's bus, so it is due immediately.
        self._next_retry: Dict[str, float] = {}
        self._obs_hedges = None  # bound lazily on first hedge

    def _notify(self, sub: Subscription, d, result=None,
                trace_id: Optional[str] = None) -> Dict[str, Any]:
        """Account one notification of one delivery; returns the outbox
        row the caller must journal (the caller owns the commit so the
        row lands in the same batch as the state that caused it)."""
        self._next_retry[d.delivery_id] = (
            time.monotonic()
            + backoff_delay(self.retry_interval, d.attempts - 1))
        self.ctx.bump("deliveries_notified")
        if d.attempts <= 1:  # first notification opens the span
            self.ctx.trace("delivery_notified", collection=d.collection,
                           trace_id=trace_id, entity=d.delivery_id,
                           data={"consumer": sub.consumer,
                                 "file": d.file})
        return outbox_message(sub, d, result=result, trace_id=trace_id)

    def _handle_output(self, m: M.Message) -> None:
        self.ctx.bump("notifications")
        # legacy broadcast: in-process consumers subscribed to the topic
        self.ctx.bus.publish(M.T_CONSUMER_NOTIFY, dict(m.body),
                             trace_id=m.trace_id)
        coll, fname = m.body.get("collection"), m.body.get("file")
        if not coll or not fname:
            return  # anonymous output: nothing to track per-file
        f = self.ctx.ddm.ensure_content(coll, fname)
        with self.ctx.lock:
            created = []
            for sub in self.ctx.subscriptions.values():
                if not sub.matches(coll):
                    continue
                d = sub.ensure_delivery(coll, fname)
                if d is not None:
                    created.append((sub, d))
        if not created:
            self.ctx.store.save_contents(coll, [f.to_dict()])
            return
        msgs = []
        ops: List[Tuple[str, Any]] = [("contents", (coll, [f.to_dict()]))]
        for sub, d in created:
            msgs.append(self._notify(sub, d, m.body.get("result"),
                                     trace_id=m.trace_id))
            ops.append(("subscription", sub.to_dict()))
        ops.append(("messages", msgs))
        # ONE commit for content row + delivery records + outbox rows
        self.ctx.store.save_many(ops)
        self.ctx.bus.publish(M.T_OUTBOX, {"count": len(msgs)},
                             trace_id=m.trace_id)

    def _retry_pass(self) -> int:
        """Re-notify overdue un-acked deliveries; fail the exhausted
        ones.  Returns how many deliveries moved."""
        now = time.monotonic()
        due, failed = [], []
        with self.ctx.lock:
            for sub in self.ctx.subscriptions.values():
                for d in sub.deliveries.values():
                    if d.status != "notified":
                        continue
                    if now < self._next_retry.get(d.delivery_id, now):
                        continue
                    if d.attempts >= self.max_notify_attempts:
                        d.set_status("failed")
                        self._next_retry.pop(d.delivery_id, None)
                        failed.append(sub)
                    else:
                        d.attempts += 1
                        due.append((sub, d))
        msgs = []
        subs_to_journal: Dict[str, Subscription] = {}
        for sub, d in due:
            self.ctx.bump("delivery_retries")
            msgs.append(self._notify(sub, d))
            subs_to_journal[sub.sub_id] = sub
        for sub in failed:
            self.ctx.bump("deliveries_failed")
            subs_to_journal[sub.sub_id] = sub
        if subs_to_journal:
            ops: List[Tuple[str, Any]] = [
                ("subscription", s.to_dict())
                for s in subs_to_journal.values()]
            if msgs:
                ops.append(("messages", msgs))
            self.ctx.store.save_many(ops)
        if msgs:
            self.ctx.bus.publish(M.T_OUTBOX, {"count": len(msgs)})
        return len(due) + len(failed)

    def _hedge_pass(self) -> int:
        """Service-level hedged re-staging: feed landed staging
        latencies to the intelligence plane's HistoryBook, then ask
        each stager to re-submit in-flight files older than
        ``hedge_headroom`` × the learned p95.  A no-op with intel off
        or before ``min_staging_samples`` — the stager's own
        median-based ``hedge_check`` still covers that cold window.
        Each record hedges at most once, so repeated passes converge
        (and a pump can quiesce)."""
        sched = getattr(self.ctx.wfm, "scheduler", None)
        intel = getattr(sched, "intel", None)
        stagers = getattr(self.ctx.ddm, "stagers", None)
        if intel is None or not callable(stagers):
            return 0
        issued = 0
        for st in stagers():
            for _name, dt in st.drain_latencies():
                intel.history.record_staging(st.collection, dt)
            p95 = intel.history.staging_p95(st.collection)
            if p95 is None:
                continue
            n = st.hedge_overdue(intel.hedge_headroom * p95)
            if n:
                intel.hedges_issued += n
                self.ctx.bump("intel_hedges", n)
                if self._obs_hedges is None and self.ctx.metrics is not None:
                    self._obs_hedges = self.ctx.metrics.counter(
                        "intel_hedges_total",
                        "learned-p95 staging hedges issued",
                        labels=("collection",))
                if self._obs_hedges is not None:
                    self._obs_hedges.labels(
                        collection=st.collection).inc(n)
                issued += n
        return issued

    def process_once(self) -> int:
        n = 0
        for m in self.ctx.bus.poll(M.T_OUTPUT_AVAILABLE):
            # outputs route to the workflow's owner: its head holds the
            # authoritative delivery bookkeeping (subscriptions from
            # other heads are hydrated by the Watchdog).  Outputs with
            # no workflow routing (external producers) process anywhere.
            if not self._owned(m, m.body.get("workflow_id")):
                continue
            n += 1
            self._handle_output(m)
        n += self._retry_pass()
        n += self._hedge_pass()
        return n


# ---------------------------------------------------------------------------
# Publisher: outbox drain -> channel fan-out
# ---------------------------------------------------------------------------


class Publisher(Daemon):
    """Drains the transactional outbox and fans messages out to their
    push channels.

    One store-claimed singleton per cluster (claim ``("outbox",
    "fanout")``): exactly one head performs fan-out at a time, and when
    it dies the claim expires and any peer's Publisher adopts the
    backlog — journaled message status is the only state, so adoption
    needs no handoff.

    Per round it loads up to ``batch_size`` undelivered rows
    (``new``/``queued`` with ``not_before`` ripe) and

      * ``bus`` channel: publishes one addressed ``T_CONSUMER_NOTIFY``
        per message (long-poll/SSE waiters and in-process consumers
        wake on it), then journals ALL status flips in one batch —
        O(batch) store writes however many subscribers matched;
      * ``webhook`` channel: groups messages by ``push_url`` and POSTs
        one JSON batch per endpoint.  A failed or timed-out POST
        re-queues its messages with full-jitter exponential
        ``not_before`` backoff, journaled per attempt; after
        ``max_notify_attempts`` the message fails and the corresponding
        delivery is circuit-broken to ``failed``.

    Crash window: a head dying between channel I/O and the status
    journal re-sends those messages after adoption (at-least-once on
    the wire); consumers deduplicate on ``msg_id``/``delivery_id``, and
    the journal itself never loses a row (exactly-once in the store).
    """
    name = "publisher"
    topics = (M.T_OUTBOX,)
    batch_size = 256           # rows drained per round
    max_notify_attempts = 5    # webhook POSTs per message before failed
    webhook_timeout = 2.0      # seconds per endpoint POST
    backoff_base = 0.2         # webhook retry backoff base (full jitter)
    backoff_cap = 30.0

    def __init__(self, ctx: Context):
        super().__init__(ctx)
        self._gauge = None
        self._delivered_c = None
        self._failed_c = None
        self._metrics_bound = False
        self._depth_dirty = True

    def _bind_metrics(self) -> None:
        if self._metrics_bound or self.ctx.metrics is None:
            return
        m = self.ctx.metrics
        self._gauge = m.gauge("outbox_depth",
                              "undelivered outbox rows").labels()
        self._delivered_c = m.counter(
            "outbox_deliveries_total", "outbox messages delivered",
            labels=("channel",))
        self._failed_c = m.counter(
            "outbox_failed_total",
            "outbox messages circuit-broken to failed",
            labels=("channel",))
        self._metrics_bound = True

    @staticmethod
    def _notify_body(msg: Dict[str, Any]) -> Dict[str, Any]:
        body = {"msg_id": msg["msg_id"], "sub_id": msg.get("sub_id"),
                "consumer": msg.get("consumer"),
                "delivery_id": msg.get("delivery_id"),
                "collection": msg.get("collection"),
                "file": msg.get("file"),
                "attempt": msg.get("delivery_attempt", 1)}
        if msg.get("seq") is not None:
            body["seq"] = msg["seq"]
        if msg.get("result") is not None:
            body["result"] = msg["result"]
        return body

    def _post(self, url: str, items: List[Dict[str, Any]]) -> bool:
        payload = {"deliveries": [self._notify_body(m) for m in items]}
        req = urllib.request.Request(
            url, data=json.dumps(payload).encode("utf-8"),
            method="POST",
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(
                    req, timeout=self.webhook_timeout) as r:
                return 200 <= r.status < 300
        except Exception:  # noqa: BLE001 — any transport failure retries
            return False

    def _circuit_break(self, msg: Dict[str, Any]) -> None:
        """A webhook endpoint exhausted its attempt budget: fail the
        tracked delivery too, so the Conductor stops re-notifying it."""
        if self._failed_c is not None:
            self._failed_c.labels(channel="webhook").inc()
        snap = None
        with self.ctx.lock:
            sub = self.ctx.subscriptions.get(msg.get("sub_id"))
            d = (sub.find_delivery(msg.get("delivery_id"))
                 if sub is not None else None)
            if d is not None and d.status == "notified":
                d.set_status("failed")
                self.ctx.bump("deliveries_failed")
                snap = sub.to_dict()
        if snap is not None:
            self.ctx.store.save_subscription(snap)

    def _fan_out(self, batch: List[Dict[str, Any]], now: float) -> int:
        bus_msgs, hooks = [], {}  # type: List[Dict], Dict[str, List[Dict]]
        for msg in batch:
            if msg.get("channel") == "webhook" and msg.get("push_url"):
                hooks.setdefault(msg["push_url"], []).append(msg)
            else:
                bus_msgs.append(msg)
        done: List[Dict[str, Any]] = []
        for msg in bus_msgs:
            self.ctx.bus.publish(M.T_CONSUMER_NOTIFY,
                                 self._notify_body(msg),
                                 trace_id=msg.get("trace_id"))
            msg["status"] = "delivered"
            msg["attempts"] = msg.get("attempts", 0) + 1
            msg["updated_at"] = now
            done.append(msg)
        if bus_msgs and self._delivered_c is not None:
            self._delivered_c.labels(channel="bus").inc(len(bus_msgs))
        for url, items in hooks.items():
            ok = self._post(url, items)  # one POST per endpoint
            for msg in items:
                msg["attempts"] = msg.get("attempts", 0) + 1
                msg["updated_at"] = now
                if ok:
                    msg["status"] = "delivered"
                    msg["not_before"] = None
                elif msg["attempts"] >= self.max_notify_attempts:
                    msg["status"] = "failed"
                    msg["not_before"] = None
                    self._circuit_break(msg)
                else:
                    msg["status"] = "queued"
                    msg["not_before"] = now + backoff_delay(
                        self.backoff_base, msg["attempts"],
                        cap=self.backoff_cap)
                done.append(msg)
            if ok and self._delivered_c is not None:
                self._delivered_c.labels(channel="webhook").inc(
                    len(items))
        # per-attempt journaling, ONE commit for the whole batch
        self.ctx.store.save_messages(done)
        self.ctx.bump("outbox_published", len(done))
        return len(done)

    def process_once(self) -> int:
        self._bind_metrics()
        # the fan-out singleton: one head drains at a time; adoption is
        # a peer's try_claim succeeding after this head's claim expires
        if not self.ctx.store.try_claim("outbox", "fanout",
                                        self.ctx.head_id,
                                        self.ctx.claim_ttl):
            return 0
        n = 0
        for _m in self.ctx.bus.poll(M.T_OUTBOX):
            n += 1  # advisory wakes; the store query is authoritative
        now = time.time()
        batch = self.ctx.store.load_messages(
            statuses=UNDELIVERED_STATUSES, due_before=now,
            limit=self.batch_size)
        if batch:
            self._fan_out(batch, now)
            n += len(batch)
            self._depth_dirty = True
        if self._gauge is not None and (batch or self._depth_dirty):
            depth = self.ctx.store.count_messages(
                statuses=UNDELIVERED_STATUSES)
            self._gauge.set(depth)
            self._depth_dirty = bool(depth)
        return n


# ---------------------------------------------------------------------------
# Commander: the steering plane (request lifecycle commands)
# ---------------------------------------------------------------------------


class Commander(Daemon):
    """Applies journaled lifecycle commands (abort/suspend/resume/retry,
    see :mod:`repro.core.commands`) to the live object graph.

    Applying is idempotent per command — a replayed ``pending`` command
    after crash recovery re-applies against state that already reflects
    it and degrades to a no-op — and the terminal transition is
    journaled *after* the effects, so the effect of every command
    happens exactly once across restarts.
    """
    name = "commander"
    topics = (M.T_NEW_COMMANDS,)

    def _hydrate_command(self, command_id: str) -> Optional[Command]:
        """Load a command journaled through ANOTHER head's REST layer
        (this head owns the target workflow, so it must apply it)."""
        for c in self.ctx.store.load_commands():
            if c["command_id"] != command_id:
                continue
            with self.ctx.lock:
                if command_id not in self.ctx.commands:
                    self.ctx.register_command(Command.from_dict(c))
                return self.ctx.commands[command_id]
        return None

    def process_once(self) -> int:
        n = 0
        for m in self.ctx.bus.poll(M.T_NEW_COMMANDS):
            if not self._owned(m, m.body.get("workflow_id")):
                continue
            n += 1
            cmd = self.ctx.commands.get(m.body["command_id"])
            if cmd is None:
                cmd = self._hydrate_command(m.body["command_id"])
            if cmd is None or not cmd.pending:
                continue  # duplicate delivery / already applied
            try:
                cmd.detail = self._apply(cmd)
                cmd.status = "done"
            except CommandConflict as e:
                cmd.status = "failed"
                cmd.error = str(e)
            except Exception as e:  # one bad command must not drop the batch
                cmd.status = "failed"
                cmd.error = f"{type(e).__name__}: {e}"
                self.ctx.bump("commander_errors")
                self.log.exception("command %s (%s) failed",
                                   cmd.command_id, cmd.action)
            cmd.processed_at = time.time()
            self.ctx.store.save_command(cmd.to_dict())
            self.ctx.bump(f"commands_{cmd.status}")
        return n

    # -- helpers -----------------------------------------------------------
    def _set_request_status(self, cmd: Command, status: str) -> None:
        with self.ctx.lock:
            info = self.ctx.requests.get(cmd.request_id)
            if info is None:
                return
            info["status"] = status
            # catalog rows carry the flag so GET /requests listings can
            # tell a steered pause from a stuck request without a
            # per-request status poll
            info["suspended"] = status == "suspended"
            snapshot = dict(info)
        self.ctx.store.save_request(snapshot)

    def _live_procs(self, wf: Workflow) -> List[Processing]:
        return [p for p in self.ctx.processings.values()
                if p.work_id in wf.works and not p.terminal]

    def _apply(self, cmd: Command) -> Dict[str, Any]:
        return getattr(self, f"_apply_{cmd.action}")(
            cmd, self.ctx.workflows.get(cmd.workflow_id))

    # -- actions -----------------------------------------------------------
    def _apply_abort(self, cmd: Command,
                     wf: Optional[Workflow]) -> Dict[str, Any]:
        wf_id = cmd.workflow_id
        with self.ctx.lock:
            # NO early-return on control == aborted: a crash mid-apply
            # journals the request row (which recover() rebuilds control
            # from) before the cancelled works, so the replayed command
            # must still cancel whatever is left.  Cancellation is
            # idempotent — a true duplicate finds nothing non-terminal.
            already = self.ctx.control.get(wf_id) == "aborted"
            self.ctx.control[wf_id] = "aborted"
            procs = self._live_procs(wf) if wf is not None else []
            for p in procs:
                p.status = ProcessingStatus.CANCELLED
                p.error = f"aborted by command {cmd.command_id}"
            works = ([w for w in wf.works.values()
                      if not w.status.terminated]
                     if wf is not None else [])
            now = time.time()
            for w in works:
                w.status = WorkStatus.CANCELLED
                w.terminated_at = now
                # cancelled works never evaluate conditions; mark them so
                # recovery cannot replay a T_WORK_DONE for them
                w.condition_evaluated = True
            work_dicts = [w.to_dict() for w in works]
            proc_dicts = [p.to_dict() for p in procs]
        if already and not works and not procs:
            return {"noop": True}  # duplicate abort: nothing left to do
        self._set_request_status(cmd, "aborted")
        if work_dicts:
            self.ctx.store.save_works(wf_id, work_dicts)
        for d in proc_dicts:
            self.ctx.store.save_processing(d)
        # revoke outstanding leases (workers observe on heartbeat) /
        # drop thread-pool futures, then let the daemons clean house
        self.ctx.wfm.cancel(procs)
        self.ctx.bus.publish(M.T_CMD_TRANSFORMER,
                             {"workflow_id": wf_id, "action": "abort"})
        self.ctx.bus.publish(M.T_CMD_CARRIER,
                             {"workflow_id": wf_id, "action": "abort"})
        self.ctx.disown(wf_id)  # terminal: stop renewing the claim
        return {"works_cancelled": len(works),
                "processings_cancelled": len(procs)}

    def _apply_suspend(self, cmd: Command,
                       wf: Optional[Workflow]) -> Dict[str, Any]:
        wf_id = cmd.workflow_id
        with self.ctx.lock:
            ctrl = self.ctx.control.get(wf_id)
            if ctrl == "aborted":
                raise CommandConflict(
                    f"request {cmd.request_id!r} is aborted")
            if ctrl == "suspended":
                return {"noop": True}
            if (wf is not None and wf.finished
                    and self.ctx.quiescent(wf_id)):
                # lost the race with completion: there is nothing to
                # fence, and flipping a finished request's catalog row
                # to "suspended" would mislabel it forever
                return {"noop": True, "reason": "request already finished"}
            self.ctx.control[wf_id] = "suspended"
            procs = self._live_procs(wf) if wf is not None else []
        self._set_request_status(cmd, "suspended")
        # fence the execution plane: live leases are revoked (the worker
        # is fenced on its next heartbeat) and pending jobs stop leasing
        self.ctx.wfm.fence(procs)
        return {"processings_fenced": len(procs)}

    def _apply_resume(self, cmd: Command,
                      wf: Optional[Workflow]) -> Dict[str, Any]:
        wf_id = cmd.workflow_id
        with self.ctx.lock:
            if self.ctx.control.get(wf_id) != "suspended":
                return {"noop": True}  # replayed after the state moved on
            del self.ctx.control[wf_id]
            procs = self._live_procs(wf) if wf is not None else []
        self._set_request_status(cmd, "running")
        self.ctx.wfm.release(procs)
        self.ctx.bus.publish(M.T_CMD_TRANSFORMER,
                             {"workflow_id": wf_id, "action": "resume"})
        self.ctx.bus.publish(M.T_CMD_CARRIER,
                             {"workflow_id": wf_id, "action": "resume"})
        return {"processings_released": len(procs)}

    def _apply_retry(self, cmd: Command,
                     wf: Optional[Workflow]) -> Dict[str, Any]:
        wf_id = cmd.workflow_id
        with self.ctx.lock:
            ctrl = self.ctx.control.get(wf_id)
            if ctrl == "aborted":
                raise CommandConflict(
                    f"request {cmd.request_id!r} is aborted")
            retried_works: List[Work] = []
            retried_procs: List[Processing] = []
            if wf is not None:
                for w in wf.works.values():
                    if w.status not in (WorkStatus.FAILED,
                                        WorkStatus.SUBFINISHED):
                        continue
                    failed = [p for p in self.ctx.processings.values()
                              if p.work_id == w.work_id
                              and p.status == ProcessingStatus.FAILED
                              and p.terminal]
                    if not failed:
                        continue
                    for p in failed:
                        p.attempt = 1  # fresh attempt budget
                        p.status = ProcessingStatus.NEW
                        p.error = None
                    w.status = WorkStatus.TRANSFORMING
                    w.terminated_at = None
                    # the re-finalize rebuilds these from the full
                    # processing set, so drop the stale merge
                    w.results = []
                    retried_works.append(w)
                    retried_procs.extend(failed)
            if not retried_works:
                return {"noop": True,
                        "reason": "no terminally failed processings"}
            work_dicts = [w.to_dict() for w in retried_works]
            proc_dicts = [p.to_dict() for p in retried_procs]
        # retrying a suspended request must not lift (or mislabel) the
        # suspension: the re-announced processings park in the Carrier
        # until an explicit resume
        self._set_request_status(
            cmd, "suspended" if ctrl == CTRL_SUSPENDED else "running")
        self.ctx.store.save_works(wf_id, work_dicts)
        for d in proc_dicts:
            self.ctx.store.save_processing(d)
        self.ctx.bump("works_retried", len(retried_works))
        # the Transformer re-owns the works and re-announces the NEW
        # processings from its own thread (it owns dispatch bookkeeping)
        self.ctx.bus.publish(M.T_CMD_TRANSFORMER, {
            "workflow_id": wf_id, "action": "retry",
            "work_ids": [w.work_id for w in retried_works]})
        return {"works_retried": len(retried_works),
                "processings_retried": len(retried_procs)}


# ---------------------------------------------------------------------------
# Watchdog: cluster coordination (health heartbeats + claim sweeping)
# ---------------------------------------------------------------------------


class Watchdog(Daemon):
    """The cluster-coordination daemon (the paper's ``Health`` table +
    ``clean_locking``).  Each head's Watchdog

      * heartbeats this head's row in the store's health table and
        renews every workflow claim the head holds;
      * sweeps for non-terminal requests whose claim is absent or
        expired — their head died without releasing — and adopts them
        through the ``adopt`` callback IDDS wires in (claim-aware
        scoped recovery), and releases claims this head still holds on
        terminal requests;
      * hydrates consumer subscriptions registered through other heads
        (and absorbs their journaled acks), so this head's Conductor
        can match outputs against them;
      * prunes bus messages past the retention window (store bus only);
      * with the intelligence plane attached: rescores queue priorities
        from observed completion rates, journals the HistoryBook's
        dirty rows into the stats table, and expires stale worker
        manifests (adaptive reprioritization, on the heartbeat cadence).

    Heartbeats, renewals, and pruning return 0 from ``process_once`` so
    a pump can quiesce; only adoptions and hydrations count as
    progress.
    """
    name = "watchdog"
    topics = ()
    bus_retention_s = 300.0

    def __init__(self, ctx: Context, *, heartbeat_interval: float = 1.0,
                 sweep_interval: Optional[float] = None):
        super().__init__(ctx)
        self.heartbeat_interval = heartbeat_interval
        self.sweep_interval = (sweep_interval if sweep_interval is not None
                               else max(ctx.claim_ttl / 2.0,
                                        heartbeat_interval))
        self.started_at = time.time()
        # monotonic due-times; everything fires on the first cycle
        self._hb_due = 0.0
        self._sweep_due = 0.0
        self._prune_due = 0.0
        # IDDS wires its claim-aware recovery here: adopt(workflow_id)
        # hydrates that workflow's object graph from the store and
        # replays its in-flight events; returns #entities restored
        self.adopt: Optional[Callable[[str], int]] = None

    def process_once(self) -> int:
        now = time.monotonic()
        moved = 0
        if now >= self._hb_due:
            self._hb_due = now + self.heartbeat_interval
            self._heartbeat()
        if now >= self._sweep_due:
            self._sweep_due = now + self.sweep_interval
            moved += self._sweep()
        if now >= self._prune_due:
            self._prune_due = now + self.bus_retention_s / 4
            prune = getattr(self.ctx.bus, "prune", None)
            if callable(prune):
                prune(self.bus_retention_s)
        return moved

    def _heartbeat(self) -> None:
        ctx = self.ctx
        with ctx.lock:
            owned = list(ctx.claimed)
        now = time.time()
        if owned:
            renewed = ctx.store.renew_claims("workflow", owned,
                                             ctx.head_id, ctx.claim_ttl,
                                             now=now)
            if renewed == len(owned):
                with ctx.lock:
                    for wf_id in owned:
                        ctx.claimed[wf_id] = now + ctx.claim_ttl
            else:
                # a claim expired and was stolen (e.g. this head stalled
                # past the TTL): trust only what the store confirms
                held = {c["entity_id"]
                        for c in ctx.store.list_claims("workflow")
                        if c["owner_id"] == ctx.head_id}
                with ctx.lock:
                    for wf_id in owned:
                        if wf_id in held:
                            ctx.claimed[wf_id] = now + ctx.claim_ttl
                        else:
                            ctx.claimed.pop(wf_id, None)
        with ctx.lock:
            n_claims = len(ctx.claimed)
        data: Dict[str, Any] = {"bus": getattr(ctx.bus, "name", "local"),
                                "claims": n_claims}
        if ctx.metrics is not None:
            sched = getattr(ctx.wfm, "scheduler", None)
            depths = getattr(sched, "queue_depths", None)
            if callable(depths):
                gauge = ctx.metrics.gauge(
                    "scheduler_queue_depth", "jobs per queue by state",
                    labels=("queue", "state"))
                for queue, states in depths().items():
                    for state, n in states.items():
                        gauge.labels(queue=queue, state=state).set(n)
            # publish this head's full metrics snapshot into the health
            # table so any peer can serve cluster-wide aggregation
            data["metrics"] = ctx.metrics.snapshot()
        ctx.store.save_health({
            "head_id": ctx.head_id,
            "started_at": self.started_at,
            "last_heartbeat": time.time(),
            "data": data,
        })
        self._intel_housekeeping()

    def _intel_housekeeping(self) -> None:
        """Adaptive reprioritization: refresh queue-priority boosts
        from observed completion rates, persist the HistoryBook's
        dirty rows, and drop expired worker manifests.  Housekeeping —
        contributes nothing to ``process_once``'s moved count, so a
        pump still quiesces."""
        ctx = self.ctx
        sched = getattr(ctx.wfm, "scheduler", None)
        intel = getattr(sched, "intel", None)
        if intel is None:
            return
        sched.rescore_queue_priorities()
        sched.prune_affinity()
        rows = intel.history.flush_dirty()
        if rows:
            ctx.store.save_stats(rows)
        if ctx.metrics is not None:
            rate = intel.affinity_hit_rate()
            if rate is not None:
                ctx.metrics.gauge(
                    "intel_affinity_hit_rate",
                    "fraction of input-bearing leases routed to a "
                    "manifest holder").labels().set(rate)

    def _sweep(self) -> int:
        ctx = self.ctx
        now = time.time()
        claims = {c["entity_id"]: c
                  for c in ctx.store.list_claims("workflow")}
        moved = 0
        for info in ctx.store.list_requests():
            wf_id = info.get("workflow_id")
            if not wf_id:
                continue
            if info.get("status") in ("finished", "aborted"):
                # housekeeping: a straggler message consumed after the
                # request turned terminal can have re-claimed it; stop
                # renewing (released claims don't count toward 'moved'
                # or a pump would never quiesce)
                c = claims.get(wf_id)
                if c is not None and c["owner_id"] == ctx.head_id:
                    ctx.disown(wf_id)
                continue
            c = claims.get(wf_id)
            if (c is not None and c["owner_id"] != ctx.head_id
                    and c["claimed_until"] >= now):
                continue  # another live head owns it
            if (wf_id in ctx.workflows and c is not None
                    and c["owner_id"] == ctx.head_id):
                continue  # already ours and hydrated
            if not ctx.try_own(wf_id):
                continue  # lost the adoption race
            if self.adopt is not None:
                moved += self.adopt(wf_id)
        moved += self._refresh_subscriptions()
        return moved

    def _refresh_subscriptions(self) -> int:
        """Hydrate subscriptions registered through other heads, and
        absorb acks they journaled, so this head's Conductor matches
        outputs against them and stops re-notifying deliveries acked
        elsewhere.  Cross-head delivery stays at-least-once: two heads
        may each notify a delivery before the journaled ack lands."""
        changed = 0
        for sd in self.ctx.store.load_subscriptions():
            with self.ctx.lock:
                sub = self.ctx.subscriptions.get(sd["sub_id"])
                if sub is None:
                    self.ctx.subscriptions[sd["sub_id"]] = \
                        Subscription.from_dict(sd)
                    changed += 1
                    continue
                for key, dd in (sd.get("deliveries") or {}).items():
                    local = sub.deliveries.get(key)
                    if (local is not None and dd.get("status") == "acked"
                            and local.status != "acked"):
                        local.set_status("acked")
                        changed += 1
        return changed


ALL_DAEMONS = (Clerk, Marshaller, Commander, Transformer, Carrier,
               Conductor, Publisher, Watchdog)
