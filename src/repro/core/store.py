"""Durable state store for the iDDS head service (paper §2 catalogs).

The paper's iDDS anchors all orchestration state — requests, transforms,
collections, contents — in database-backed Restful catalogs so daemons
coordinate through shared state and the service survives restarts.  This
module is that persistence boundary for the reproduction:

  * :class:`Store`         — the narrow interface daemons journal through;
  * :class:`InMemoryStore` — dict-backed, zero overhead, no durability
                             (unit tests, simulators, benchmarks);
  * :class:`SqliteStore`   — stdlib ``sqlite3`` in WAL mode with one
                             connection per thread, so the six daemon
                             threads and the REST pool write concurrently.

Entities are journaled as JSON blobs keyed by their natural primary key,
with the columns needed for catalog queries (status filtering, pagination)
lifted out.  ``IDDS.recover()`` replays a store into a fresh head service
after a crash; see docs/architecture.md for the recovery semantics.
"""
from __future__ import annotations

import json
import sqlite3
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

from .obs import SLOW_OP_THRESHOLD_S as _SLOW_FLUSH_S, get_logger

_log = get_logger("store")


class StoreError(Exception):
    """The backing file is unusable (corrupt, wrong format, locked away)."""


# Request catalog statuses a client may filter on (GET /requests?status=).
# "suspended"/"aborted" are entered via lifecycle commands (commands.py).
VALID_REQUEST_STATUSES = ("new", "accepted", "running", "suspended",
                          "finished", "failed", "aborted")

# Content rows only ever advance through the state machine (new ->
# staging -> available -> failed/delivered), but they are journaled from
# several threads (stager pool, daemon threads) whose point-in-time
# snapshots can commit out of order — a stager's "available" write
# queued behind the write lock must not clobber the "delivered" row the
# Transformer committed meanwhile.  Upserts therefore apply only when
# the incoming row does not REGRESS the stored rank (lost-update guard).
# "failed" ranks BELOW "available": failed -> available is the one legal
# backward transition (a hedge landing after the original request
# exhausted its attempts — live state takes the landing, so the journal
# must too), while available -> failed cannot happen (set_failed no-ops
# once a file is available).
_CONTENT_RANK = {"new": 0, "staging": 1, "failed": 2, "available": 3,
                 "delivered": 4}


def _content_rank(status: Optional[str]) -> int:
    return _CONTENT_RANK.get(status or "", 0)


class Store:
    """Journal + catalog for head-service state.

    ``save_*`` methods are upserts keyed on the entity's id and must be
    safe to call from any daemon thread.  ``load_*`` methods return
    plain dicts in insertion order — `recover()` reassembles the object
    graph from them.  Implementations must make ``save_works`` atomic:
    the Marshaller journals a terminated Work together with the
    successors its conditions spawned, and a crash must never persist
    one without the other (that is what makes recovery exactly-once).
    """

    # -- requests ---------------------------------------------------------
    def save_request(self, info: Dict[str, Any]) -> None:
        raise NotImplementedError

    def get_request(self, request_id: str) -> Optional[Dict[str, Any]]:
        raise NotImplementedError

    def list_requests(self, *, status: Optional[str] = None,
                      limit: Optional[int] = None,
                      offset: int = 0) -> List[Dict[str, Any]]:
        raise NotImplementedError

    def count_requests(self, *, status: Optional[str] = None) -> int:
        raise NotImplementedError

    # -- workflows (structure only; works journaled separately) -----------
    def save_workflow(self, wf: Dict[str, Any]) -> None:
        raise NotImplementedError

    def load_workflows(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- works -------------------------------------------------------------
    def save_works(self, workflow_id: str,
                   works: List[Dict[str, Any]]) -> None:
        """Upsert a batch of works atomically (all or none)."""
        raise NotImplementedError

    def save_work(self, workflow_id: str, work: Dict[str, Any]) -> None:
        self.save_works(workflow_id, [work])

    def load_works(self) -> List[Tuple[str, Dict[str, Any]]]:
        """Every persisted work as ``(workflow_id, work_dict)``."""
        raise NotImplementedError

    # -- processings --------------------------------------------------------
    def save_processing(self, proc: Dict[str, Any]) -> None:
        raise NotImplementedError

    def load_processings(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- leases (distributed execution plane) ------------------------------
    def save_lease(self, lease: Dict[str, Any]) -> None:
        """Upsert one lease row keyed on ``job_id`` (the scheduler
        journals grants and renewals so a head crash mid-lease can be
        audited and the lease requeued by ``recover()``)."""
        raise NotImplementedError

    def save_leases_bulk(self, leases: List[Dict[str, Any]]) -> None:
        """Upsert many lease rows in one journal commit (the scheduler's
        multi-lease and batch-heartbeat paths).  The default loops over
        :meth:`save_lease`; backends override with a single transaction."""
        for lease in leases:
            self.save_lease(lease)

    def delete_lease(self, job_id: str) -> None:
        raise NotImplementedError

    def load_leases(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- lifecycle commands (steering plane) -------------------------------
    def save_command(self, cmd: Dict[str, Any]) -> None:
        """Upsert one command row keyed on ``command_id``.  Commands are
        journaled ``pending`` before they are announced and ``done``/
        ``failed`` after they apply, so ``recover()`` can replay the
        in-flight ones exactly once."""
        raise NotImplementedError

    def load_commands(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- collections + contents --------------------------------------------
    def save_collection(self, coll: Dict[str, Any]) -> None:
        """Upsert a collection and its per-file contents."""
        raise NotImplementedError

    def save_contents(self, collection: str,
                      files: List[Dict[str, Any]]) -> None:
        """Upsert only the given content rows (a full ``save_collection``
        rewrite is O(files); state transitions touch one file at a
        time)."""
        raise NotImplementedError

    def save_contents_bulk(
            self, batches: List[Tuple[str, List[Dict[str, Any]]]]) -> None:
        """Upsert content rows for many collections in one journal
        commit.  Each batch is ``(collection, files)``; the per-row rank
        guard of :meth:`save_contents` applies unchanged.  The default
        loops; backends override with a single transaction."""
        for collection, files in batches:
            if files:
                self.save_contents(collection, files)

    def load_collections(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- consumer subscriptions (delivery plane) ---------------------------
    def save_subscription(self, sub: Dict[str, Any]) -> None:
        """Upsert one subscription row keyed on ``sub_id``; the row
        embeds the subscription's delivery records, so the Conductor
        journals every delivery transition through this call."""
        raise NotImplementedError

    def load_subscriptions(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- outbox messages (push delivery plane) -----------------------------
    # The transactional outbox: the Conductor journals one message row
    # per delivery IN THE SAME save_many BATCH as the subscription /
    # content transition that caused it, so a crash can never persist
    # the state change without its notification (or vice versa).  The
    # Publisher daemon later drains rows by status — new/queued rows are
    # undelivered work it re-drives after a crash; ``not_before`` (WALL
    # clock, cross-process like claims) parks a row between webhook
    # retry attempts.  The store assigns each row a monotonically
    # increasing ``seq`` on first insert (preserved on upsert): it is
    # the global delivery-event cursor SSE resume rides on.

    def save_message(self, msg: Dict[str, Any]) -> None:
        """Upsert one outbox row keyed on ``msg_id``."""
        self.save_messages([msg])

    def save_messages(self, msgs: List[Dict[str, Any]]) -> None:
        """Upsert a batch of outbox rows atomically (all or none)."""
        raise NotImplementedError

    def load_messages(self, *, sub_id: Optional[str] = None,
                      statuses: Optional[Iterable[str]] = None,
                      after_seq: Optional[int] = None,
                      due_before: Optional[float] = None,
                      limit: Optional[int] = None
                      ) -> List[Dict[str, Any]]:
        """Outbox rows ordered by ``seq``, with optional filters:
        ``statuses`` (e.g. the Publisher's undelivered set), ``after_seq``
        (an SSE resume cursor), ``due_before`` (skip rows whose
        ``not_before`` has not ripened) and ``limit`` (drain batch
        size)."""
        raise NotImplementedError

    def count_messages(self, *, statuses: Optional[Iterable[str]] = None
                       ) -> int:
        """Outbox row count (the telemetry depth gauge)."""
        raise NotImplementedError

    # -- ownership claims (multi-head coordination) ------------------------
    # Claims are how N head processes share one catalog without stepping
    # on each other (the paper's row-level locking: TransformLocking /
    # clean_locking).  A claim row is (kind, entity_id) -> (owner_id,
    # claimed_until); ``try_claim`` is an atomic compare-and-claim that
    # succeeds iff the row is absent, expired, or already owned by the
    # caller (renewal).  ``claimed_until`` is WALL-clock time — it must
    # be comparable across processes, so ``time.monotonic`` cannot be
    # used here.

    def try_claim(self, kind: str, entity_id: str, owner_id: str,
                  ttl_s: float, now: Optional[float] = None) -> bool:
        """Atomically claim (or renew) an entity; True on success."""
        raise NotImplementedError

    def release_claim(self, kind: str, entity_id: str,
                      owner_id: str) -> bool:
        """Drop a claim iff still held by ``owner_id``; True if dropped."""
        raise NotImplementedError

    def renew_claims(self, kind: str, entity_ids: Iterable[str],
                     owner_id: str, ttl_s: float,
                     now: Optional[float] = None) -> int:
        """Extend ``claimed_until`` on every listed entity still owned
        by ``owner_id``; returns how many were renewed."""
        raise NotImplementedError

    def get_claim(self, kind: str,
                  entity_id: str) -> Optional[Dict[str, Any]]:
        raise NotImplementedError

    def list_claims(self, kind: Optional[str] = None
                    ) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- head health (heartbeat table) -------------------------------------
    def save_health(self, info: Dict[str, Any]) -> None:
        """Upsert one head's heartbeat row keyed on ``head_id``."""
        raise NotImplementedError

    def load_health(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- intelligence-plane stats ------------------------------------------
    # Learned history journaled by repro.core.intel.HistoryBook: one row
    # per (scope, key) — e.g. ("queue", "tape") — holding a small JSON
    # aggregate (EWMA latency, completion tallies).  Upserted, never
    # appended, so the table stays O(queues) and a restarted head warm
    # starts instead of re-learning from scratch.

    def save_stats(self, rows: List[Dict[str, Any]]) -> None:
        """Upsert stats rows keyed on ``(scope, key)``; each row is
        ``{"scope", "key", "data": dict, "updated_at": wall}``."""
        raise NotImplementedError

    def load_stats(self, scope: Optional[str] = None
                   ) -> List[Dict[str, Any]]:
        """All stats rows (optionally one scope), unordered."""
        raise NotImplementedError

    # -- trace events (telemetry plane) ------------------------------------
    # Request-lifecycle events journaled by repro.core.obs.Tracer: each
    # row attributes one hop (submitted, workflow_started, job_leased,
    # content_available, ...) to the head that performed it, with a
    # wall-clock ``ts`` so peers' rows interleave correctly.  Safe to
    # lose (diagnostics, not state), so BufferedStore coalesces them.

    def save_trace_events(self, rows: List[Dict[str, Any]]) -> None:
        """Append trace-event rows (idempotent per ``event_id``)."""
        raise NotImplementedError

    def load_trace_events(self, request_id: Optional[str] = None,
                          collections: Optional[Iterable[str]] = None
                          ) -> List[Dict[str, Any]]:
        """Events for one request and/or a set of collections (the
        trace endpoint joins a request to its works' collections);
        both None returns everything.  Ordered by timestamp."""
        raise NotImplementedError

    # -- store-backed message queue (StorePollingBus) ----------------------
    # A durable bus_messages journal lets a second head's daemons wake on
    # the first head's announcements.  Two delivery modes, chosen by the
    # bus layer per topic: ``bus_consume`` is consumed-once cluster-wide
    # (work-queue topics), ``bus_fetch_after`` is a cursor read every
    # head performs independently (broadcast topics — fetch never marks
    # rows consumed).

    def bus_publish(self, topic: str, body: Dict[str, Any],
                    now: Optional[float] = None,
                    origin: Optional[str] = None,
                    not_before: Optional[float] = None) -> int:
        """Append one message; returns its monotonically increasing id.
        ``origin`` records the publishing head (consumers use it to skip
        re-firing their own broadcast callbacks); ``not_before`` delays
        redelivery of a requeued message so the requeueing head does not
        busy-spin re-consuming it before the owner's next poll."""
        raise NotImplementedError

    def bus_consume(self, topics: Iterable[str], consumer: str,
                    max_n: int = 0, now: Optional[float] = None
                    ) -> List[Dict[str, Any]]:
        """Atomically take ripe unconsumed messages on ``topics`` (each
        row goes to exactly one caller cluster-wide); ``max_n`` 0 =
        all.  A message with ``not_before`` in the future is invisible
        until it ripens."""
        raise NotImplementedError

    def bus_fetch_after(self, topics: Iterable[str], after_id: int,
                        max_n: int = 0) -> List[Dict[str, Any]]:
        """Read messages with id > ``after_id`` without consuming them."""
        raise NotImplementedError

    def bus_max_id(self) -> int:
        raise NotImplementedError

    def bus_depth(self, topics: Optional[Iterable[str]] = None,
                  now: Optional[float] = None) -> int:
        """Ripe unconsumed message count (optionally per topics)."""
        raise NotImplementedError

    def bus_prune(self, older_than: float) -> int:
        """Delete messages created before ``older_than`` (wall clock),
        consumed or not — a retention window, not a consumption check
        (broadcast rows are never marked consumed)."""
        raise NotImplementedError

    # -- generic batched journaling ----------------------------------------
    # ``save_many`` applies an ordered list of journal operations; SQLite
    # coalesces the whole list into ONE transaction (one fsync-eligible
    # commit instead of len(ops)).  Op shapes:
    #   ("request", info)            ("workflow", wf)
    #   ("works", (workflow_id, works))   ("processing", proc)
    #   ("lease", lease)             ("delete_lease", job_id)
    #   ("command", cmd)             ("collection", coll)
    #   ("contents", (collection, files)) ("subscription", sub)
    #   ("messages", [msg, ...])          ("stats", [row, ...])
    def _apply_op(self, kind: str, payload: Any) -> None:
        if kind == "contents":
            self.save_contents(payload[0], payload[1])
        elif kind == "lease":
            self.save_lease(payload)
        elif kind == "delete_lease":
            self.delete_lease(payload)
        elif kind == "processing":
            self.save_processing(payload)
        elif kind == "collection":
            self.save_collection(payload)
        elif kind == "subscription":
            self.save_subscription(payload)
        elif kind == "messages":
            self.save_messages(payload)
        elif kind == "request":
            self.save_request(payload)
        elif kind == "workflow":
            self.save_workflow(payload)
        elif kind == "works":
            self.save_works(payload[0], payload[1])
        elif kind == "command":
            self.save_command(payload)
        elif kind == "trace_events":
            self.save_trace_events(payload)
        elif kind == "stats":
            self.save_stats(payload)
        else:
            raise ValueError(f"unknown store op kind {kind!r}")

    def save_many(self, ops: List[Tuple[str, Any]]) -> None:
        """Apply journal ops in order, coalesced into one commit where
        the backend supports it.  The default applies them one by one."""
        for kind, payload in ops:
            self._apply_op(kind, payload)

    # -- telemetry ----------------------------------------------------------
    # Class-attribute defaults keep the unbound check a single attribute
    # lookup on the save_many hot path (no __init__ changes needed in
    # subclasses that never bind a registry).
    _obs_write_hist = None
    _obs_write_ops = None

    def bind_metrics(self, registry: Any) -> None:
        """Attach an ``obs.MetricsRegistry``: journal commits get a
        per-backend latency histogram and an op counter."""
        backend = type(self).__name__
        self._obs_write_hist = registry.histogram(
            "store_write_seconds",
            "journal write (save_many commit) duration",
            labels=("backend",)).labels(backend=backend)
        self._obs_write_ops = registry.counter(
            "store_write_ops_total", "journal ops written",
            labels=("backend",)).labels(backend=backend)

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# In-memory (no durability; the pre-PR behaviour, now behind the interface)
# ---------------------------------------------------------------------------


class InMemoryStore(Store):
    """Dict-backed store: same journaling surface, nothing survives the
    process.  Keeps the hot path allocation-cheap for simulators and the
    in-memory arm of ``benchmarks/store_bench.py``."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._requests: Dict[str, Dict[str, Any]] = {}
        self._workflows: Dict[str, Dict[str, Any]] = {}
        self._works: Dict[str, Tuple[str, Dict[str, Any]]] = {}
        self._processings: Dict[str, Dict[str, Any]] = {}
        self._collections: Dict[str, Dict[str, Any]] = {}
        self._leases: Dict[str, Dict[str, Any]] = {}
        self._commands: Dict[str, Dict[str, Any]] = {}
        self._subscriptions: Dict[str, Dict[str, Any]] = {}
        self._messages: Dict[str, Dict[str, Any]] = {}
        self._msg_next_seq = 1
        self._claims: Dict[Tuple[str, str], Dict[str, Any]] = {}
        self._health: Dict[str, Dict[str, Any]] = {}
        self._stats: Dict[Tuple[str, str], Dict[str, Any]] = {}
        self._trace_events: List[Dict[str, Any]] = []
        self._trace_seen: set = set()
        self._bus_msgs: List[Dict[str, Any]] = []
        self._bus_next_id = 1

    def save_request(self, info: Dict[str, Any]) -> None:
        with self._lock:
            self._requests[info["request_id"]] = dict(info)

    def get_request(self, request_id: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            info = self._requests.get(request_id)
            return dict(info) if info is not None else None

    def list_requests(self, *, status: Optional[str] = None,
                      limit: Optional[int] = None,
                      offset: int = 0) -> List[Dict[str, Any]]:
        with self._lock:
            rows = [dict(r) for r in self._requests.values()
                    if status is None or r.get("status") == status]
        end = None if limit is None else offset + limit
        return rows[offset:end]

    def count_requests(self, *, status: Optional[str] = None) -> int:
        with self._lock:
            return sum(1 for r in self._requests.values()
                       if status is None or r.get("status") == status)

    def save_workflow(self, wf: Dict[str, Any]) -> None:
        with self._lock:
            self._workflows[wf["workflow_id"]] = dict(wf)

    def load_workflows(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(w) for w in self._workflows.values()]

    def save_works(self, workflow_id: str,
                   works: List[Dict[str, Any]]) -> None:
        with self._lock:
            for w in works:
                self._works[w["work_id"]] = (workflow_id, dict(w))

    def load_works(self) -> List[Tuple[str, Dict[str, Any]]]:
        with self._lock:
            return [(wf_id, dict(w))
                    for wf_id, w in self._works.values()]

    def save_processing(self, proc: Dict[str, Any]) -> None:
        with self._lock:
            self._processings[proc["proc_id"]] = dict(proc)

    def load_processings(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(p) for p in self._processings.values()]

    def save_lease(self, lease: Dict[str, Any]) -> None:
        with self._lock:
            self._leases[lease["job_id"]] = dict(lease)

    def save_leases_bulk(self, leases: List[Dict[str, Any]]) -> None:
        with self._lock:  # one acquisition for the whole batch
            for lease in leases:
                self._leases[lease["job_id"]] = dict(lease)

    def delete_lease(self, job_id: str) -> None:
        with self._lock:
            self._leases.pop(job_id, None)

    def load_leases(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(le) for le in self._leases.values()]

    def save_command(self, cmd: Dict[str, Any]) -> None:
        with self._lock:
            self._commands[cmd["command_id"]] = dict(cmd)

    def load_commands(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(c) for c in self._commands.values()]

    def _merge_contents(self, coll: Dict[str, Any],
                        files: List[Dict[str, Any]]) -> None:
        index = {f["name"]: i for i, f in enumerate(coll["files"])}
        for f in files:
            f = json.loads(json.dumps(f))
            i = index.get(f["name"])
            if i is None:
                index[f["name"]] = len(coll["files"])
                coll["files"].append(f)
            elif (_content_rank(f.get("status"))
                  >= _content_rank(coll["files"][i].get("status"))):
                coll["files"][i] = f

    def save_collection(self, coll: Dict[str, Any]) -> None:
        with self._lock:
            existing = self._collections.setdefault(
                coll["name"], {"name": coll["name"],
                               "scope": coll.get("scope", "idds"),
                               "files": []})
            existing["scope"] = coll.get("scope", "idds")
            self._merge_contents(existing, coll.get("files", []))

    def save_contents(self, collection: str,
                      files: List[Dict[str, Any]]) -> None:
        with self._lock:
            coll = self._collections.setdefault(
                collection, {"name": collection, "scope": "idds",
                             "files": []})
            self._merge_contents(coll, files)

    def save_contents_bulk(
            self, batches: List[Tuple[str, List[Dict[str, Any]]]]) -> None:
        with self._lock:  # one acquisition for the whole batch
            for collection, files in batches:
                if files:
                    self.save_contents(collection, files)

    def save_many(self, ops: List[Tuple[str, Any]]) -> None:
        t0 = time.monotonic() if self._obs_write_hist is not None else 0.0
        with self._lock:  # RLock: nested save_* reacquisitions are free
            for kind, payload in ops:
                self._apply_op(kind, payload)
        if self._obs_write_hist is not None:
            self._obs_write_hist.observe(time.monotonic() - t0)
            self._obs_write_ops.inc(len(ops))

    def load_collections(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [json.loads(json.dumps(c))
                    for c in self._collections.values()]

    def save_subscription(self, sub: Dict[str, Any]) -> None:
        with self._lock:
            self._subscriptions[sub["sub_id"]] = json.loads(json.dumps(sub))

    def load_subscriptions(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [json.loads(json.dumps(s))
                    for s in self._subscriptions.values()]

    # -- outbox messages ----------------------------------------------------
    def save_messages(self, msgs: List[Dict[str, Any]]) -> None:
        with self._lock:
            for m in msgs:
                m = json.loads(json.dumps(m))
                prev = self._messages.get(m["msg_id"])
                if prev is not None:  # seq is assigned once, on insert
                    m["seq"] = prev["seq"]
                else:
                    m["seq"] = self._msg_next_seq
                    self._msg_next_seq += 1
                self._messages[m["msg_id"]] = m

    def load_messages(self, *, sub_id: Optional[str] = None,
                      statuses: Optional[Iterable[str]] = None,
                      after_seq: Optional[int] = None,
                      due_before: Optional[float] = None,
                      limit: Optional[int] = None
                      ) -> List[Dict[str, Any]]:
        sset = None if statuses is None else set(statuses)
        with self._lock:
            rows = [json.loads(json.dumps(m))
                    for m in self._messages.values()
                    if (sub_id is None or m.get("sub_id") == sub_id)
                    and (sset is None or m.get("status") in sset)
                    and (after_seq is None or m["seq"] > after_seq)
                    and (due_before is None
                         or (m.get("not_before") or 0.0) <= due_before)]
        rows.sort(key=lambda m: m["seq"])
        return rows if limit is None else rows[:limit]

    def count_messages(self, *, statuses: Optional[Iterable[str]] = None
                       ) -> int:
        sset = None if statuses is None else set(statuses)
        with self._lock:
            return sum(1 for m in self._messages.values()
                       if sset is None or m.get("status") in sset)

    # -- ownership claims ---------------------------------------------------
    def try_claim(self, kind: str, entity_id: str, owner_id: str,
                  ttl_s: float, now: Optional[float] = None) -> bool:
        now = time.time() if now is None else now
        with self._lock:
            c = self._claims.get((kind, entity_id))
            if (c is not None and c["owner_id"] != owner_id
                    and c["claimed_until"] >= now):
                return False  # live claim held by another owner
            self._claims[(kind, entity_id)] = {
                "kind": kind, "entity_id": entity_id,
                "owner_id": owner_id, "claimed_until": now + ttl_s}
            return True

    def release_claim(self, kind: str, entity_id: str,
                      owner_id: str) -> bool:
        with self._lock:
            c = self._claims.get((kind, entity_id))
            if c is None or c["owner_id"] != owner_id:
                return False
            del self._claims[(kind, entity_id)]
            return True

    def renew_claims(self, kind: str, entity_ids: Iterable[str],
                     owner_id: str, ttl_s: float,
                     now: Optional[float] = None) -> int:
        now = time.time() if now is None else now
        renewed = 0
        with self._lock:
            for entity_id in entity_ids:
                c = self._claims.get((kind, entity_id))
                if c is not None and c["owner_id"] == owner_id:
                    c["claimed_until"] = now + ttl_s
                    renewed += 1
        return renewed

    def get_claim(self, kind: str,
                  entity_id: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            c = self._claims.get((kind, entity_id))
            return dict(c) if c is not None else None

    def list_claims(self, kind: Optional[str] = None
                    ) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(c) for c in self._claims.values()
                    if kind is None or c["kind"] == kind]

    # -- head health --------------------------------------------------------
    def save_health(self, info: Dict[str, Any]) -> None:
        with self._lock:
            self._health[info["head_id"]] = dict(info)

    def load_health(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(h) for h in self._health.values()]

    # -- intelligence-plane stats -------------------------------------------
    def save_stats(self, rows: List[Dict[str, Any]]) -> None:
        with self._lock:
            for row in rows:
                self._stats[(row["scope"], row["key"])] = {
                    "scope": row["scope"], "key": row["key"],
                    "data": json.loads(json.dumps(row.get("data", {}))),
                    "updated_at": row.get("updated_at")}

    def load_stats(self, scope: Optional[str] = None
                   ) -> List[Dict[str, Any]]:
        with self._lock:
            return [json.loads(json.dumps(r))
                    for (sc, _k), r in self._stats.items()
                    if scope is None or sc == scope]

    def save_trace_events(self, rows: List[Dict[str, Any]]) -> None:
        with self._lock:
            for r in rows:
                ev_id = r.get("event_id")
                if ev_id in self._trace_seen:
                    continue  # replayed batch (e.g. a re-flushed buffer)
                self._trace_seen.add(ev_id)
                self._trace_events.append(dict(r))

    def load_trace_events(self, request_id: Optional[str] = None,
                          collections: Optional[Iterable[str]] = None
                          ) -> List[Dict[str, Any]]:
        colls = set(collections) if collections else set()
        with self._lock:
            rows = [dict(r) for r in self._trace_events
                    if (request_id is None and not colls)
                    or (request_id is not None
                        and r.get("request_id") == request_id)
                    or r.get("collection") in colls]
        rows.sort(key=lambda r: r.get("ts") or 0.0)
        return rows

    # -- store-backed message queue -----------------------------------------
    # bodies are stored as JSON text for copy semantics (and parity with
    # the SQLite backend): a consumer mutating its dict must not mutate
    # the journaled message
    def bus_publish(self, topic: str, body: Dict[str, Any],
                    now: Optional[float] = None,
                    origin: Optional[str] = None,
                    not_before: Optional[float] = None) -> int:
        now = time.time() if now is None else now
        with self._lock:
            msg_id = self._bus_next_id
            self._bus_next_id += 1
            self._bus_msgs.append({
                "msg_id": msg_id, "topic": topic,
                "body": json.dumps(body), "created_at": now,
                "origin": origin, "not_before": not_before,
                "consumed_by": None, "consumed_at": None})
            return msg_id

    def bus_consume(self, topics: Iterable[str], consumer: str,
                    max_n: int = 0, now: Optional[float] = None
                    ) -> List[Dict[str, Any]]:
        now = time.time() if now is None else now
        tset = set(topics)
        out: List[Dict[str, Any]] = []
        with self._lock:
            for m in self._bus_msgs:
                if (m["consumed_by"] is None and m["topic"] in tset
                        and (m["not_before"] is None
                             or m["not_before"] <= now)):
                    m["consumed_by"] = consumer
                    m["consumed_at"] = now
                    out.append({"msg_id": m["msg_id"],
                                "topic": m["topic"],
                                "body": json.loads(m["body"]),
                                "origin": m["origin"]})
                    if max_n and len(out) >= max_n:
                        break
        return out

    def bus_fetch_after(self, topics: Iterable[str], after_id: int,
                        max_n: int = 0) -> List[Dict[str, Any]]:
        tset = set(topics)
        out: List[Dict[str, Any]] = []
        with self._lock:
            for m in self._bus_msgs:
                if m["msg_id"] > after_id and m["topic"] in tset:
                    out.append({"msg_id": m["msg_id"],
                                "topic": m["topic"],
                                "body": json.loads(m["body"]),
                                "origin": m["origin"]})
                    if max_n and len(out) >= max_n:
                        break
        return out

    def bus_max_id(self) -> int:
        with self._lock:
            return self._bus_next_id - 1

    def bus_depth(self, topics: Optional[Iterable[str]] = None,
                  now: Optional[float] = None) -> int:
        now = time.time() if now is None else now
        tset = None if topics is None else set(topics)
        with self._lock:
            return sum(1 for m in self._bus_msgs
                       if m["consumed_by"] is None
                       and (tset is None or m["topic"] in tset)
                       and (m["not_before"] is None
                            or m["not_before"] <= now))

    def bus_prune(self, older_than: float) -> int:
        with self._lock:
            before = len(self._bus_msgs)
            self._bus_msgs = [m for m in self._bus_msgs
                              if m["created_at"] >= older_than]
            return before - len(self._bus_msgs)


# ---------------------------------------------------------------------------
# SQLite (WAL mode, one connection per thread)
# ---------------------------------------------------------------------------


_SCHEMA = """
CREATE TABLE IF NOT EXISTS requests (
    request_id   TEXT PRIMARY KEY,
    workflow_id  TEXT,
    requester    TEXT,
    status       TEXT,
    submitted_at REAL,
    data         TEXT NOT NULL,
    seq          INTEGER
);
CREATE INDEX IF NOT EXISTS idx_requests_status ON requests (status);
CREATE TABLE IF NOT EXISTS workflows (
    workflow_id TEXT PRIMARY KEY,
    name        TEXT,
    data        TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS works (
    work_id     TEXT PRIMARY KEY,
    workflow_id TEXT,
    status      TEXT,
    data        TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_works_workflow ON works (workflow_id);
CREATE TABLE IF NOT EXISTS processings (
    proc_id TEXT PRIMARY KEY,
    work_id TEXT,
    status  TEXT,
    data    TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_processings_work ON processings (work_id);
CREATE TABLE IF NOT EXISTS leases (
    job_id     TEXT PRIMARY KEY,
    worker_id  TEXT,
    queue      TEXT,
    expires_at REAL,
    data       TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS commands (
    command_id TEXT PRIMARY KEY,
    request_id TEXT,
    action     TEXT,
    status     TEXT,
    created_at REAL,
    data       TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_commands_request ON commands (request_id);
CREATE TABLE IF NOT EXISTS collections (
    name  TEXT PRIMARY KEY,
    scope TEXT
);
CREATE TABLE IF NOT EXISTS contents (
    collection TEXT,
    name       TEXT,
    size       INTEGER,
    available  INTEGER,
    processed  INTEGER,
    status     TEXT,
    created_at REAL,
    updated_at REAL,
    PRIMARY KEY (collection, name)
);
CREATE TABLE IF NOT EXISTS subscriptions (
    sub_id   TEXT PRIMARY KEY,
    consumer TEXT,
    data     TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS messages (
    seq        INTEGER PRIMARY KEY AUTOINCREMENT,
    msg_id     TEXT UNIQUE,
    sub_id     TEXT,
    status     TEXT,
    not_before REAL,
    created_at REAL,
    data       TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_messages_status
    ON messages (status, not_before);
CREATE INDEX IF NOT EXISTS idx_messages_sub ON messages (sub_id, seq);
CREATE TABLE IF NOT EXISTS claims (
    kind          TEXT,
    entity_id     TEXT,
    owner_id      TEXT,
    claimed_until REAL,
    PRIMARY KEY (kind, entity_id)
);
CREATE INDEX IF NOT EXISTS idx_claims_owner ON claims (owner_id);
CREATE TABLE IF NOT EXISTS health (
    head_id        TEXT PRIMARY KEY,
    started_at     REAL,
    last_heartbeat REAL,
    data           TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS bus_messages (
    msg_id      INTEGER PRIMARY KEY AUTOINCREMENT,
    topic       TEXT,
    body        TEXT NOT NULL,
    created_at  REAL,
    origin      TEXT,
    not_before  REAL,
    consumed_by TEXT,
    consumed_at REAL
);
CREATE INDEX IF NOT EXISTS idx_bus_unconsumed
    ON bus_messages (topic) WHERE consumed_by IS NULL;
CREATE TABLE IF NOT EXISTS trace_events (
    event_id   TEXT PRIMARY KEY,
    trace_id   TEXT,
    request_id TEXT,
    collection TEXT,
    event      TEXT,
    entity     TEXT,
    head_id    TEXT,
    ts         REAL,
    data       TEXT
);
CREATE INDEX IF NOT EXISTS idx_trace_request ON trace_events (request_id);
CREATE INDEX IF NOT EXISTS idx_trace_collection
    ON trace_events (collection);
CREATE TABLE IF NOT EXISTS stats (
    scope      TEXT,
    key        TEXT,
    data       TEXT NOT NULL,
    updated_at REAL,
    PRIMARY KEY (scope, key)
);
"""

# columns added to `contents` after the table first shipped: pre-existing
# store files are migrated in place on open (ALTER TABLE ADD COLUMN)
_CONTENTS_MIGRATIONS = (("status", "TEXT"), ("created_at", "REAL"),
                        ("updated_at", "REAL"))


class SqliteStore(Store):
    """Single-file durable store.

    WAL journal mode lets daemon threads write while REST threads read;
    ``synchronous=NORMAL`` bounds fsync cost to WAL checkpoints (the
    store journals ~10 small rows per workflow — FULL would fsync each).
    sqlite3 connections are not thread-safe, so each thread lazily opens
    its own (`threading.local`); all of them are closed by ``close()``.
    """

    def __init__(self, path: str):
        self.path = path
        self._local = threading.local()
        self._all_conns: List[sqlite3.Connection] = []
        self._conns_lock = threading.Lock()
        # validate the file up front: recover() must fail loudly on a
        # corrupt store, not silently return an empty catalog
        conn = self._conn()
        try:
            conn.execute("SELECT count(*) FROM requests").fetchone()
        except sqlite3.DatabaseError as e:  # pragma: no cover - re-raise
            raise StoreError(f"unusable store file {path!r}: {e}") from e

    def _conn(self) -> sqlite3.Connection:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            return conn
        try:
            # check_same_thread=False: each connection is only USED by
            # its owning thread while live, but close() must be able to
            # reap them all from whichever thread tears the store down
            conn = sqlite3.connect(self.path, timeout=30.0,
                                   isolation_level=None,
                                   check_same_thread=False)
            conn.execute("PRAGMA journal_mode=WAL")
            conn.execute("PRAGMA synchronous=NORMAL")
            conn.executescript(_SCHEMA)
            have = {r[1] for r in
                    conn.execute("PRAGMA table_info(contents)")}
            for col, decl in _CONTENTS_MIGRATIONS:
                if col not in have:
                    conn.execute(
                        f"ALTER TABLE contents ADD COLUMN {col} {decl}")
            # after the migration: the column exists on every schema
            conn.execute("CREATE INDEX IF NOT EXISTS idx_contents_status"
                         " ON contents (collection, status)")
        except sqlite3.DatabaseError as e:
            raise StoreError(
                f"unusable store file {self.path!r}: {e}") from e
        self._local.conn = conn
        with self._conns_lock:
            self._all_conns.append(conn)
        return conn

    # -- requests ---------------------------------------------------------
    _REQUEST_UPSERT = (
        "INSERT INTO requests (request_id, workflow_id, requester,"
        " status, submitted_at, data, seq) VALUES (?, ?, ?, ?, ?, ?,"
        " (SELECT COALESCE(MAX(seq), 0) + 1 FROM requests))"
        " ON CONFLICT(request_id) DO UPDATE SET"
        " status=excluded.status, data=excluded.data")

    @staticmethod
    def _request_row(info: Dict[str, Any]) -> Tuple:
        return (info["request_id"], info.get("workflow_id"),
                info.get("requester"), info.get("status"),
                info.get("submitted_at"), json.dumps(info))

    def save_request(self, info: Dict[str, Any]) -> None:
        self._conn().execute(self._REQUEST_UPSERT, self._request_row(info))

    def get_request(self, request_id: str) -> Optional[Dict[str, Any]]:
        row = self._conn().execute(
            "SELECT data FROM requests WHERE request_id = ?",
            (request_id,)).fetchone()
        return json.loads(row[0]) if row else None

    def list_requests(self, *, status: Optional[str] = None,
                      limit: Optional[int] = None,
                      offset: int = 0) -> List[Dict[str, Any]]:
        sql = "SELECT data FROM requests"
        args: List[Any] = []
        if status is not None:
            sql += " WHERE status = ?"
            args.append(status)
        # LIMIT is required before OFFSET in sqlite; -1 means unbounded
        sql += " ORDER BY seq LIMIT ? OFFSET ?"
        args += [-1 if limit is None else limit, offset]
        rows = self._conn().execute(sql, args).fetchall()
        return [json.loads(r[0]) for r in rows]

    def count_requests(self, *, status: Optional[str] = None) -> int:
        if status is None:
            row = self._conn().execute(
                "SELECT count(*) FROM requests").fetchone()
        else:
            row = self._conn().execute(
                "SELECT count(*) FROM requests WHERE status = ?",
                (status,)).fetchone()
        return int(row[0])

    # -- workflows ---------------------------------------------------------
    _WORKFLOW_UPSERT = (
        "INSERT INTO workflows (workflow_id, name, data)"
        " VALUES (?, ?, ?) ON CONFLICT(workflow_id) DO UPDATE SET"
        " data=excluded.data")

    def save_workflow(self, wf: Dict[str, Any]) -> None:
        self._conn().execute(
            self._WORKFLOW_UPSERT,
            (wf["workflow_id"], wf.get("name"), json.dumps(wf)))

    def load_workflows(self) -> List[Dict[str, Any]]:
        rows = self._conn().execute(
            "SELECT data FROM workflows ORDER BY rowid").fetchall()
        return [json.loads(r[0]) for r in rows]

    # -- works -------------------------------------------------------------
    _WORK_UPSERT = (
        "INSERT INTO works (work_id, workflow_id, status, data)"
        " VALUES (?, ?, ?, ?) ON CONFLICT(work_id) DO UPDATE SET"
        " status=excluded.status, data=excluded.data")

    def save_works(self, workflow_id: str,
                   works: List[Dict[str, Any]]) -> None:
        if not works:
            return
        self.save_many([("works", (workflow_id, works))])

    def load_works(self) -> List[Tuple[str, Dict[str, Any]]]:
        rows = self._conn().execute(
            "SELECT workflow_id, data FROM works ORDER BY rowid").fetchall()
        return [(r[0], json.loads(r[1])) for r in rows]

    # -- processings --------------------------------------------------------
    _PROC_UPSERT = (
        "INSERT INTO processings (proc_id, work_id, status, data)"
        " VALUES (?, ?, ?, ?) ON CONFLICT(proc_id) DO UPDATE SET"
        " status=excluded.status, data=excluded.data")

    def save_processing(self, proc: Dict[str, Any]) -> None:
        self._conn().execute(
            self._PROC_UPSERT,
            (proc["proc_id"], proc.get("work_id"), proc.get("status"),
             json.dumps(proc)))

    def load_processings(self) -> List[Dict[str, Any]]:
        rows = self._conn().execute(
            "SELECT data FROM processings ORDER BY rowid").fetchall()
        return [json.loads(r[0]) for r in rows]

    # -- leases --------------------------------------------------------------
    _LEASE_UPSERT = (
        "INSERT INTO leases (job_id, worker_id, queue, expires_at,"
        " data) VALUES (?, ?, ?, ?, ?)"
        " ON CONFLICT(job_id) DO UPDATE SET"
        " worker_id=excluded.worker_id, expires_at=excluded.expires_at,"
        " data=excluded.data")

    @staticmethod
    def _lease_row(lease: Dict[str, Any]) -> Tuple:
        return (lease["job_id"], lease.get("worker_id"),
                lease.get("queue"), lease.get("expires_at"),
                json.dumps(lease))

    def save_lease(self, lease: Dict[str, Any]) -> None:
        self._conn().execute(self._LEASE_UPSERT, self._lease_row(lease))

    def save_leases_bulk(self, leases: List[Dict[str, Any]]) -> None:
        if not leases:
            return
        self.save_many([("lease", le) for le in leases])

    def delete_lease(self, job_id: str) -> None:
        self._conn().execute("DELETE FROM leases WHERE job_id = ?",
                             (job_id,))

    def load_leases(self) -> List[Dict[str, Any]]:
        rows = self._conn().execute(
            "SELECT data FROM leases ORDER BY rowid").fetchall()
        return [json.loads(r[0]) for r in rows]

    # -- commands ------------------------------------------------------------
    _COMMAND_UPSERT = (
        "INSERT INTO commands (command_id, request_id, action,"
        " status, created_at, data) VALUES (?, ?, ?, ?, ?, ?)"
        " ON CONFLICT(command_id) DO UPDATE SET"
        " status=excluded.status, data=excluded.data")

    def save_command(self, cmd: Dict[str, Any]) -> None:
        self._conn().execute(
            self._COMMAND_UPSERT,
            (cmd["command_id"], cmd.get("request_id"), cmd.get("action"),
             cmd.get("status"), cmd.get("created_at"), json.dumps(cmd)))

    def load_commands(self) -> List[Dict[str, Any]]:
        rows = self._conn().execute(
            "SELECT data FROM commands ORDER BY rowid").fetchall()
        return [json.loads(r[0]) for r in rows]

    # -- collections --------------------------------------------------------
    _RANK_SQL = ("CASE IFNULL({col}, '') WHEN 'staging' THEN 1"
                 " WHEN 'failed' THEN 2 WHEN 'available' THEN 3"
                 " WHEN 'delivered' THEN 4 ELSE 0 END")
    # the WHERE clause is the lost-update guard: see _CONTENT_RANK
    _CONTENT_UPSERT = (
        "INSERT INTO contents (collection, name, size, available,"
        " processed, status, created_at, updated_at)"
        " VALUES (?, ?, ?, ?, ?, ?, ?, ?)"
        " ON CONFLICT(collection, name) DO UPDATE SET"
        " size=excluded.size, available=excluded.available,"
        " processed=excluded.processed, status=excluded.status,"
        " created_at=excluded.created_at, updated_at=excluded.updated_at"
        " WHERE " + _RANK_SQL.format(col="excluded.status")
        + " >= " + _RANK_SQL.format(col="contents.status"))

    @staticmethod
    def _content_row(collection: str, f: Dict[str, Any]) -> Tuple:
        return (collection, f["name"], f.get("size", 0),
                int(bool(f.get("available"))),
                int(bool(f.get("processed"))), f.get("status"),
                f.get("created_at"), f.get("updated_at"))

    _COLLECTION_UPSERT = (
        "INSERT INTO collections (name, scope) VALUES (?, ?)"
        " ON CONFLICT(name) DO UPDATE SET scope=excluded.scope")
    _COLLECTION_ENSURE = (
        "INSERT OR IGNORE INTO collections (name, scope)"
        " VALUES (?, 'idds')")

    def save_collection(self, coll: Dict[str, Any]) -> None:
        self.save_many([("collection", coll)])

    def save_contents(self, collection: str,
                      files: List[Dict[str, Any]]) -> None:
        if not files:
            return
        self.save_many([("contents", (collection, files))])

    def save_contents_bulk(
            self, batches: List[Tuple[str, List[Dict[str, Any]]]]) -> None:
        ops = [("contents", (c, fs)) for c, fs in batches if fs]
        if ops:
            self.save_many(ops)

    def load_collections(self) -> List[Dict[str, Any]]:
        conn = self._conn()
        colls = conn.execute(
            "SELECT name, scope FROM collections ORDER BY rowid").fetchall()
        out = []
        for name, scope in colls:
            files = conn.execute(
                "SELECT name, size, available, processed, status,"
                " created_at, updated_at FROM contents"
                " WHERE collection = ? ORDER BY rowid", (name,)).fetchall()
            out.append({"name": name, "scope": scope,
                        "files": [{"name": f[0], "size": f[1],
                                   "available": bool(f[2]),
                                   "processed": bool(f[3]),
                                   "status": f[4],
                                   "created_at": f[5],
                                   "updated_at": f[6]}
                                  for f in files]})
        return out

    # -- subscriptions -------------------------------------------------------
    _SUB_UPSERT = (
        "INSERT INTO subscriptions (sub_id, consumer, data)"
        " VALUES (?, ?, ?) ON CONFLICT(sub_id) DO UPDATE SET"
        " data=excluded.data")

    def save_subscription(self, sub: Dict[str, Any]) -> None:
        self._conn().execute(
            self._SUB_UPSERT,
            (sub["sub_id"], sub.get("consumer"), json.dumps(sub)))

    def load_subscriptions(self) -> List[Dict[str, Any]]:
        rows = self._conn().execute(
            "SELECT data FROM subscriptions ORDER BY rowid").fetchall()
        return [json.loads(r[0]) for r in rows]

    # -- outbox messages ----------------------------------------------------
    # ON CONFLICT leaves ``seq`` alone: the AUTOINCREMENT value assigned
    # on first insert is the SSE resume cursor and must never move.
    _MESSAGE_UPSERT = (
        "INSERT INTO messages (msg_id, sub_id, status, not_before,"
        " created_at, data) VALUES (?, ?, ?, ?, ?, ?)"
        " ON CONFLICT(msg_id) DO UPDATE SET"
        " status=excluded.status, not_before=excluded.not_before,"
        " data=excluded.data")

    @staticmethod
    def _message_row(m: Dict[str, Any]) -> Tuple:
        return (m["msg_id"], m.get("sub_id"), m.get("status"),
                m.get("not_before"), m.get("created_at"), json.dumps(m))

    def save_messages(self, msgs: List[Dict[str, Any]]) -> None:
        if msgs:
            self.save_many([("messages", msgs)])

    def load_messages(self, *, sub_id: Optional[str] = None,
                      statuses: Optional[Iterable[str]] = None,
                      after_seq: Optional[int] = None,
                      due_before: Optional[float] = None,
                      limit: Optional[int] = None
                      ) -> List[Dict[str, Any]]:
        sql = "SELECT seq, data FROM messages"
        clauses, args = [], []  # type: List[str], List[Any]
        if sub_id is not None:
            clauses.append("sub_id = ?")
            args.append(sub_id)
        if statuses is not None:
            sts = list(statuses)
            if not sts:
                return []
            qs = ",".join("?" * len(sts))
            clauses.append(f"status IN ({qs})")
            args.extend(sts)
        if after_seq is not None:
            clauses.append("seq > ?")
            args.append(after_seq)
        if due_before is not None:
            clauses.append("(not_before IS NULL OR not_before <= ?)")
            args.append(due_before)
        if clauses:
            sql += " WHERE " + " AND ".join(clauses)
        sql += " ORDER BY seq LIMIT ?"
        args.append(-1 if limit is None else limit)
        out = []
        for seq, data in self._conn().execute(sql, args).fetchall():
            m = json.loads(data)
            m["seq"] = int(seq)  # authoritative: data may predate insert
            out.append(m)
        return out

    def count_messages(self, *, statuses: Optional[Iterable[str]] = None
                       ) -> int:
        if statuses is None:
            row = self._conn().execute(
                "SELECT count(*) FROM messages").fetchone()
        else:
            sts = list(statuses)
            if not sts:
                return 0
            qs = ",".join("?" * len(sts))
            row = self._conn().execute(
                f"SELECT count(*) FROM messages WHERE status IN ({qs})",
                sts).fetchone()
        return int(row[0])

    # -- ownership claims ---------------------------------------------------
    # The WHERE clause makes the upsert a compare-and-claim: the UPDATE
    # half applies only when the caller already owns the row (renewal)
    # or the existing claim has expired.  sqlite3 reports rowcount 0
    # when the WHERE excludes the update, which is the "another head
    # holds a live claim" answer — one statement, atomic under SQLite's
    # write lock, no read-then-write race between heads.
    _CLAIM_UPSERT = (
        "INSERT INTO claims (kind, entity_id, owner_id, claimed_until)"
        " VALUES (?, ?, ?, ?)"
        " ON CONFLICT(kind, entity_id) DO UPDATE SET"
        " owner_id=excluded.owner_id,"
        " claimed_until=excluded.claimed_until"
        " WHERE claims.owner_id = excluded.owner_id"
        " OR claims.claimed_until < ?")

    def try_claim(self, kind: str, entity_id: str, owner_id: str,
                  ttl_s: float, now: Optional[float] = None) -> bool:
        now = time.time() if now is None else now
        cur = self._conn().execute(
            self._CLAIM_UPSERT,
            (kind, entity_id, owner_id, now + ttl_s, now))
        return cur.rowcount > 0

    def release_claim(self, kind: str, entity_id: str,
                      owner_id: str) -> bool:
        cur = self._conn().execute(
            "DELETE FROM claims WHERE kind = ? AND entity_id = ?"
            " AND owner_id = ?", (kind, entity_id, owner_id))
        return cur.rowcount > 0

    def renew_claims(self, kind: str, entity_ids: Iterable[str],
                     owner_id: str, ttl_s: float,
                     now: Optional[float] = None) -> int:
        ids = list(entity_ids)
        if not ids:
            return 0
        now = time.time() if now is None else now
        qs = ",".join("?" * len(ids))
        cur = self._conn().execute(
            f"UPDATE claims SET claimed_until = ? WHERE kind = ?"
            f" AND owner_id = ? AND entity_id IN ({qs})",
            [now + ttl_s, kind, owner_id, *ids])
        return cur.rowcount

    def get_claim(self, kind: str,
                  entity_id: str) -> Optional[Dict[str, Any]]:
        row = self._conn().execute(
            "SELECT owner_id, claimed_until FROM claims"
            " WHERE kind = ? AND entity_id = ?",
            (kind, entity_id)).fetchone()
        if row is None:
            return None
        return {"kind": kind, "entity_id": entity_id,
                "owner_id": row[0], "claimed_until": row[1]}

    def list_claims(self, kind: Optional[str] = None
                    ) -> List[Dict[str, Any]]:
        sql = ("SELECT kind, entity_id, owner_id, claimed_until"
               " FROM claims")
        args: List[Any] = []
        if kind is not None:
            sql += " WHERE kind = ?"
            args.append(kind)
        rows = self._conn().execute(sql, args).fetchall()
        return [{"kind": r[0], "entity_id": r[1], "owner_id": r[2],
                 "claimed_until": r[3]} for r in rows]

    # -- head health --------------------------------------------------------
    _HEALTH_UPSERT = (
        "INSERT INTO health (head_id, started_at, last_heartbeat, data)"
        " VALUES (?, ?, ?, ?) ON CONFLICT(head_id) DO UPDATE SET"
        " started_at=excluded.started_at,"
        " last_heartbeat=excluded.last_heartbeat, data=excluded.data")

    def save_health(self, info: Dict[str, Any]) -> None:
        self._conn().execute(
            self._HEALTH_UPSERT,
            (info["head_id"], info.get("started_at"),
             info.get("last_heartbeat"), json.dumps(info)))

    def load_health(self) -> List[Dict[str, Any]]:
        rows = self._conn().execute(
            "SELECT data FROM health ORDER BY rowid").fetchall()
        return [json.loads(r[0]) for r in rows]

    # -- intelligence-plane stats -------------------------------------------
    _STATS_UPSERT = (
        "INSERT INTO stats (scope, key, data, updated_at)"
        " VALUES (?, ?, ?, ?) ON CONFLICT(scope, key) DO UPDATE SET"
        " data=excluded.data, updated_at=excluded.updated_at")

    @staticmethod
    def _stats_row(r: Dict[str, Any]) -> Tuple[Any, ...]:
        return (r["scope"], r["key"], json.dumps(r.get("data", {})),
                r.get("updated_at"))

    def save_stats(self, rows: List[Dict[str, Any]]) -> None:
        if rows:
            self.save_many([("stats", rows)])

    def load_stats(self, scope: Optional[str] = None
                   ) -> List[Dict[str, Any]]:
        sql = "SELECT scope, key, data, updated_at FROM stats"
        args: List[Any] = []
        if scope is not None:
            sql += " WHERE scope = ?"
            args.append(scope)
        rows = self._conn().execute(sql, args).fetchall()
        return [{"scope": r[0], "key": r[1], "data": json.loads(r[2]),
                 "updated_at": r[3]} for r in rows]

    # -- trace events --------------------------------------------------------
    # OR IGNORE: event_id is globally unique, so a re-flushed buffer
    # batch replays as a no-op instead of an IntegrityError
    _TRACE_INSERT = (
        "INSERT OR IGNORE INTO trace_events (event_id, trace_id,"
        " request_id, collection, event, entity, head_id, ts, data)"
        " VALUES (?, ?, ?, ?, ?, ?, ?, ?, ?)")

    @staticmethod
    def _trace_row(r: Dict[str, Any]) -> Tuple[Any, ...]:
        data = r.get("data")
        return (r.get("event_id"), r.get("trace_id"), r.get("request_id"),
                r.get("collection"), r.get("event"), r.get("entity"),
                r.get("head_id"), r.get("ts"),
                json.dumps(data) if data is not None else None)

    def save_trace_events(self, rows: List[Dict[str, Any]]) -> None:
        if rows:
            self.save_many([("trace_events", rows)])

    def load_trace_events(self, request_id: Optional[str] = None,
                          collections: Optional[Iterable[str]] = None
                          ) -> List[Dict[str, Any]]:
        colls = list(collections) if collections else []
        sql = ("SELECT event_id, trace_id, request_id, collection,"
               " event, entity, head_id, ts, data FROM trace_events")
        clauses, args = [], []  # type: List[str], List[Any]
        if request_id is not None:
            clauses.append("request_id = ?")
            args.append(request_id)
        if colls:
            qs = ",".join("?" * len(colls))
            clauses.append(f"collection IN ({qs})")
            args.extend(colls)
        if clauses:
            sql += " WHERE " + " OR ".join(clauses)
        sql += " ORDER BY ts, event_id"
        out = []
        for r in self._conn().execute(sql, args).fetchall():
            row = {"event_id": r[0], "trace_id": r[1], "request_id": r[2],
                   "collection": r[3], "event": r[4], "entity": r[5],
                   "head_id": r[6], "ts": r[7]}
            if r[8] is not None:
                row["data"] = json.loads(r[8])
            out.append(row)
        return out

    # -- store-backed message queue -----------------------------------------
    def bus_publish(self, topic: str, body: Dict[str, Any],
                    now: Optional[float] = None,
                    origin: Optional[str] = None,
                    not_before: Optional[float] = None) -> int:
        now = time.time() if now is None else now
        cur = self._conn().execute(
            "INSERT INTO bus_messages (topic, body, created_at, origin,"
            " not_before) VALUES (?, ?, ?, ?, ?)",
            (topic, json.dumps(body), now, origin, not_before))
        return int(cur.lastrowid)

    def bus_consume(self, topics: Iterable[str], consumer: str,
                    max_n: int = 0, now: Optional[float] = None
                    ) -> List[Dict[str, Any]]:
        topics = list(topics)
        if not topics:
            return []
        now = time.time() if now is None else now
        conn = self._conn()
        qs = ",".join("?" * len(topics))
        rows = conn.execute(
            f"SELECT msg_id, topic, body, origin FROM bus_messages"
            f" WHERE consumed_by IS NULL AND topic IN ({qs})"
            f" AND (not_before IS NULL OR not_before <= ?)"
            f" ORDER BY msg_id LIMIT ?",
            [*topics, now, max_n if max_n else -1]).fetchall()
        out: List[Dict[str, Any]] = []
        for msg_id, topic, body, origin in rows:
            # per-row compare-and-set: rowcount 0 means another head won
            # the race between our SELECT and this UPDATE — skip the row
            cur = conn.execute(
                "UPDATE bus_messages SET consumed_by = ?,"
                " consumed_at = ? WHERE msg_id = ?"
                " AND consumed_by IS NULL", (consumer, now, msg_id))
            if cur.rowcount:
                out.append({"msg_id": msg_id, "topic": topic,
                            "body": json.loads(body), "origin": origin})
        return out

    def bus_fetch_after(self, topics: Iterable[str], after_id: int,
                        max_n: int = 0) -> List[Dict[str, Any]]:
        topics = list(topics)
        if not topics:
            return []
        qs = ",".join("?" * len(topics))
        rows = self._conn().execute(
            f"SELECT msg_id, topic, body, origin FROM bus_messages"
            f" WHERE msg_id > ? AND topic IN ({qs})"
            f" ORDER BY msg_id LIMIT ?",
            [after_id, *topics, max_n if max_n else -1]).fetchall()
        return [{"msg_id": r[0], "topic": r[1],
                 "body": json.loads(r[2]), "origin": r[3]} for r in rows]

    def bus_max_id(self) -> int:
        row = self._conn().execute(
            "SELECT COALESCE(MAX(msg_id), 0) FROM bus_messages"
        ).fetchone()
        return int(row[0])

    def bus_depth(self, topics: Optional[Iterable[str]] = None,
                  now: Optional[float] = None) -> int:
        now = time.time() if now is None else now
        if topics is None:
            row = self._conn().execute(
                "SELECT count(*) FROM bus_messages"
                " WHERE consumed_by IS NULL"
                " AND (not_before IS NULL OR not_before <= ?)",
                (now,)).fetchone()
        else:
            topics = list(topics)
            if not topics:
                return 0
            qs = ",".join("?" * len(topics))
            row = self._conn().execute(
                f"SELECT count(*) FROM bus_messages"
                f" WHERE consumed_by IS NULL AND topic IN ({qs})"
                f" AND (not_before IS NULL OR not_before <= ?)",
                [*topics, now]).fetchone()
        return int(row[0])

    def bus_prune(self, older_than: float) -> int:
        cur = self._conn().execute(
            "DELETE FROM bus_messages WHERE created_at < ?",
            (older_than,))
        return cur.rowcount

    # -- generic batched journaling ----------------------------------------
    def _apply_op_conn(self, conn: sqlite3.Connection, kind: str,
                       payload: Any) -> None:
        """One op's statements, no transaction management (the caller
        owns the enclosing BEGIN/COMMIT)."""
        if kind == "contents":
            collection, files = payload
            conn.execute(self._COLLECTION_ENSURE, (collection,))
            conn.executemany(
                self._CONTENT_UPSERT,
                [self._content_row(collection, f) for f in files])
        elif kind == "lease":
            conn.execute(self._LEASE_UPSERT, self._lease_row(payload))
        elif kind == "delete_lease":
            conn.execute("DELETE FROM leases WHERE job_id = ?", (payload,))
        elif kind == "processing":
            conn.execute(
                self._PROC_UPSERT,
                (payload["proc_id"], payload.get("work_id"),
                 payload.get("status"), json.dumps(payload)))
        elif kind == "collection":
            conn.execute(self._COLLECTION_UPSERT,
                         (payload["name"], payload.get("scope", "idds")))
            conn.executemany(
                self._CONTENT_UPSERT,
                [self._content_row(payload["name"], f)
                 for f in payload.get("files", [])])
        elif kind == "subscription":
            conn.execute(
                self._SUB_UPSERT,
                (payload["sub_id"], payload.get("consumer"),
                 json.dumps(payload)))
        elif kind == "messages":
            conn.executemany(self._MESSAGE_UPSERT,
                             [self._message_row(m) for m in payload])
        elif kind == "request":
            conn.execute(self._REQUEST_UPSERT, self._request_row(payload))
        elif kind == "workflow":
            conn.execute(
                self._WORKFLOW_UPSERT,
                (payload["workflow_id"], payload.get("name"),
                 json.dumps(payload)))
        elif kind == "works":
            workflow_id, works = payload
            conn.executemany(
                self._WORK_UPSERT,
                [(w["work_id"], workflow_id, w.get("status"),
                  json.dumps(w)) for w in works])
        elif kind == "command":
            conn.execute(
                self._COMMAND_UPSERT,
                (payload["command_id"], payload.get("request_id"),
                 payload.get("action"), payload.get("status"),
                 payload.get("created_at"), json.dumps(payload)))
        elif kind == "trace_events":
            conn.executemany(self._TRACE_INSERT,
                             [self._trace_row(r) for r in payload])
        elif kind == "stats":
            conn.executemany(self._STATS_UPSERT,
                             [self._stats_row(r) for r in payload])
        else:
            raise ValueError(f"unknown store op kind {kind!r}")

    def save_many(self, ops: List[Tuple[str, Any]]) -> None:
        """All ops in ONE transaction: one write-lock grab and one
        fsync-eligible commit, which is where the SQLite bulk speedup
        comes from.  Atomic: a crash persists all ops or none."""
        if not ops:
            return
        t0 = time.monotonic() if self._obs_write_hist is not None else 0.0
        conn = self._conn()
        conn.execute("BEGIN IMMEDIATE")
        try:
            for kind, payload in ops:
                self._apply_op_conn(conn, kind, payload)
            conn.execute("COMMIT")
        except BaseException:
            conn.execute("ROLLBACK")
            raise
        if self._obs_write_hist is not None:
            self._obs_write_hist.observe(time.monotonic() - t0)
            self._obs_write_ops.inc(len(ops))

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        with self._conns_lock:
            conns, self._all_conns = self._all_conns, []
        for conn in conns:
            try:
                conn.close()
            except sqlite3.Error:  # pragma: no cover - best effort
                pass
        self._local = threading.local()


# ---------------------------------------------------------------------------
# Write-coalescing buffer (optional decorator on either backend)
# ---------------------------------------------------------------------------


class BufferedStore(Store):
    """Coalesces the hot journal writes of an inner store into batched
    ``save_many`` commits.

    Only the ops that are safe to lose in a crash window are buffered —
    content upserts (rank-guarded, so replaying them in any order or not
    at all never corrupts state) and lease save/delete (``recover()``
    drops every journaled lease as an orphan anyway).  Requests,
    workflows, works, processings, commands and subscriptions pass
    straight through: losing one of those rows would break the
    exactly-once recovery invariants, so they are never delayed.

    A buffered op becomes durable at the next flush, which happens when

      * the buffer reaches ``max_batch`` ops (flushed inline), or
      * the background flusher ticks (every ``flush_interval_ms``), or
      * any read (``load_*``/``get_*``/``list_*``/``count_*``) runs —
        read-your-writes, or
      * ``close()`` is called.

    Crash semantics: at most the last ``flush_interval_ms`` of content/
    lease journal traffic is lost — the same loss class the SQLite
    backend already accepts with ``synchronous=NORMAL`` — and a failed
    flush re-queues its ops in order, so transient store errors delay
    rather than drop them.  See docs/architecture.md.
    """

    _BUFFERED_KINDS = frozenset({"contents", "lease", "delete_lease",
                                 "trace_events", "stats"})

    def __init__(self, inner: Store, *, flush_interval_ms: float = 25.0,
                 max_batch: int = 256):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if flush_interval_ms <= 0:
            raise ValueError("flush_interval_ms must be > 0")
        self.inner = inner
        self.flush_interval_ms = float(flush_interval_ms)
        self.max_batch = int(max_batch)
        self._ops: List[Tuple[str, Any]] = []
        self._lock = threading.Lock()       # guards the op buffer
        self._flush_lock = threading.Lock()  # serializes flushes (order!)
        self._stop = threading.Event()
        self._flusher: Optional[threading.Thread] = None
        # counters (stats/healthz introspection; tests assert coalescing)
        self.flushes = 0
        self.coalesced_ops = 0

    # ------------------------------------------------------------ flushing
    def _flush_loop(self) -> None:
        while not self._stop.wait(self.flush_interval_ms / 1000.0):
            try:
                self.flush()
            except Exception:  # pragma: no cover — retried next tick
                pass

    def _buffer(self, kind: str, payload: Any) -> None:
        with self._lock:
            self._ops.append((kind, payload))
            n = len(self._ops)
            if self._flusher is None:  # lazy: no thread until first write
                self._flusher = threading.Thread(
                    target=self._flush_loop, daemon=True,
                    name="store-flusher")
                self._flusher.start()
        if n >= self.max_batch:
            self.flush()

    def pending(self) -> int:
        with self._lock:
            return len(self._ops)

    def flush(self) -> int:
        """Drain the buffer into one ``save_many`` commit; returns the
        number of ops flushed.  On failure the ops are re-queued at the
        front so ordering is preserved for the retry."""
        with self._flush_lock:
            with self._lock:
                ops, self._ops = self._ops, []
            if not ops:
                return 0
            t0 = time.monotonic()
            try:
                self.inner.save_many(ops)
            except BaseException:
                with self._lock:
                    self._ops[:0] = ops
                raise
            self.flushes += 1
            self.coalesced_ops += len(ops)
            dt = time.monotonic() - t0
            if self._obs_flush_hist is not None:
                self._obs_flush_hist.observe(dt)
                self._obs_flush_batch.observe(len(ops))
            if dt > _SLOW_FLUSH_S:
                _log.warning("slow store flush: %d ops in %.3fs",
                             len(ops), dt)
            return len(ops)

    # -- telemetry -----------------------------------------------------------
    _obs_flush_hist = None
    _obs_flush_batch = None

    def bind_metrics(self, registry: Any) -> None:
        """Instrument the inner backend's commits plus this buffer's
        flush latency and batch-size distribution."""
        self.inner.bind_metrics(registry)
        self._obs_flush_hist = registry.histogram(
            "store_flush_seconds",
            "BufferedStore flush duration").labels()
        self._obs_flush_batch = registry.histogram(
            "store_flush_batch_ops",
            "ops coalesced per BufferedStore flush").labels()

    def save_trace_events(self, rows: List[Dict[str, Any]]) -> None:
        if rows:  # safe-to-lose diagnostics: coalesced like contents
            self._buffer("trace_events", [dict(r) for r in rows])

    def load_trace_events(self, request_id: Optional[str] = None,
                          collections: Optional[Iterable[str]] = None
                          ) -> List[Dict[str, Any]]:
        self.flush()
        return self.inner.load_trace_events(request_id=request_id,
                                            collections=collections)

    def save_stats(self, rows: List[Dict[str, Any]]) -> None:
        if rows:  # learned aggregates: losing a flush window re-learns
            self._buffer("stats", [dict(r) for r in rows])

    def load_stats(self, scope: Optional[str] = None
                   ) -> List[Dict[str, Any]]:
        self.flush()
        return self.inner.load_stats(scope=scope)

    # ----------------------------------------------------- buffered writes
    def save_contents(self, collection: str,
                      files: List[Dict[str, Any]]) -> None:
        if files:  # copy: callers may mutate their dicts before the flush
            self._buffer("contents", (collection, [dict(f) for f in files]))

    def save_contents_bulk(
            self, batches: List[Tuple[str, List[Dict[str, Any]]]]) -> None:
        for collection, files in batches:
            self.save_contents(collection, files)

    def save_lease(self, lease: Dict[str, Any]) -> None:
        self._buffer("lease", dict(lease))

    def save_leases_bulk(self, leases: List[Dict[str, Any]]) -> None:
        for lease in leases:
            self.save_lease(lease)

    def delete_lease(self, job_id: str) -> None:
        self._buffer("delete_lease", job_id)

    # ------------------------------------------- pass-through writes
    # (never delayed: recovery depends on these rows being durable the
    # moment the daemon's journal call returns)
    def save_request(self, info: Dict[str, Any]) -> None:
        self.inner.save_request(info)

    def save_workflow(self, wf: Dict[str, Any]) -> None:
        self.inner.save_workflow(wf)

    def save_works(self, workflow_id: str,
                   works: List[Dict[str, Any]]) -> None:
        self.inner.save_works(workflow_id, works)

    def save_processing(self, proc: Dict[str, Any]) -> None:
        self.inner.save_processing(proc)

    def save_command(self, cmd: Dict[str, Any]) -> None:
        self.inner.save_command(cmd)

    def save_collection(self, coll: Dict[str, Any]) -> None:
        self.inner.save_collection(coll)

    def save_subscription(self, sub: Dict[str, Any]) -> None:
        self.inner.save_subscription(sub)

    def save_messages(self, msgs: List[Dict[str, Any]]) -> None:
        # the outbox IS the crash-safety mechanism for notifications;
        # buffering it would reopen the loss window it exists to close
        self.inner.save_messages(msgs)

    # ----------------------- multi-head plane (never buffered)
    # Claims, health heartbeats and bus messages exist to coordinate
    # OTHER processes; holding them in a local buffer would make another
    # head observe stale ownership, so every call goes straight through.
    def try_claim(self, kind: str, entity_id: str, owner_id: str,
                  ttl_s: float, now: Optional[float] = None) -> bool:
        return self.inner.try_claim(kind, entity_id, owner_id, ttl_s,
                                    now=now)

    def release_claim(self, kind: str, entity_id: str,
                      owner_id: str) -> bool:
        return self.inner.release_claim(kind, entity_id, owner_id)

    def renew_claims(self, kind: str, entity_ids: Iterable[str],
                     owner_id: str, ttl_s: float,
                     now: Optional[float] = None) -> int:
        return self.inner.renew_claims(kind, entity_ids, owner_id,
                                       ttl_s, now=now)

    def get_claim(self, kind: str,
                  entity_id: str) -> Optional[Dict[str, Any]]:
        return self.inner.get_claim(kind, entity_id)

    def list_claims(self, kind: Optional[str] = None
                    ) -> List[Dict[str, Any]]:
        return self.inner.list_claims(kind)

    def save_health(self, info: Dict[str, Any]) -> None:
        self.inner.save_health(info)

    def load_health(self) -> List[Dict[str, Any]]:
        return self.inner.load_health()

    def bus_publish(self, topic: str, body: Dict[str, Any],
                    now: Optional[float] = None,
                    origin: Optional[str] = None,
                    not_before: Optional[float] = None) -> int:
        return self.inner.bus_publish(topic, body, now=now,
                                      origin=origin,
                                      not_before=not_before)

    def bus_consume(self, topics: Iterable[str], consumer: str,
                    max_n: int = 0, now: Optional[float] = None
                    ) -> List[Dict[str, Any]]:
        return self.inner.bus_consume(topics, consumer, max_n=max_n,
                                      now=now)

    def bus_fetch_after(self, topics: Iterable[str], after_id: int,
                        max_n: int = 0) -> List[Dict[str, Any]]:
        return self.inner.bus_fetch_after(topics, after_id, max_n=max_n)

    def bus_max_id(self) -> int:
        return self.inner.bus_max_id()

    def bus_depth(self, topics: Optional[Iterable[str]] = None,
                  now: Optional[float] = None) -> int:
        return self.inner.bus_depth(topics, now=now)

    def bus_prune(self, older_than: float) -> int:
        return self.inner.bus_prune(older_than)

    def save_many(self, ops: List[Tuple[str, Any]]) -> None:
        # mixed batches keep strict ordering: drain the buffer first,
        # then commit the caller's ops in one inner transaction
        self.flush()
        self.inner.save_many(ops)

    # ------------------------------------------------- reads (flush first)
    def get_request(self, request_id: str) -> Optional[Dict[str, Any]]:
        self.flush()
        return self.inner.get_request(request_id)

    def list_requests(self, *, status: Optional[str] = None,
                      limit: Optional[int] = None,
                      offset: int = 0) -> List[Dict[str, Any]]:
        self.flush()
        return self.inner.list_requests(status=status, limit=limit,
                                        offset=offset)

    def count_requests(self, *, status: Optional[str] = None) -> int:
        self.flush()
        return self.inner.count_requests(status=status)

    def load_workflows(self) -> List[Dict[str, Any]]:
        self.flush()
        return self.inner.load_workflows()

    def load_works(self) -> List[Tuple[str, Dict[str, Any]]]:
        self.flush()
        return self.inner.load_works()

    def load_processings(self) -> List[Dict[str, Any]]:
        self.flush()
        return self.inner.load_processings()

    def load_leases(self) -> List[Dict[str, Any]]:
        self.flush()
        return self.inner.load_leases()

    def load_commands(self) -> List[Dict[str, Any]]:
        self.flush()
        return self.inner.load_commands()

    def load_collections(self) -> List[Dict[str, Any]]:
        self.flush()
        return self.inner.load_collections()

    def load_subscriptions(self) -> List[Dict[str, Any]]:
        self.flush()
        return self.inner.load_subscriptions()

    def load_messages(self, *, sub_id: Optional[str] = None,
                      statuses: Optional[Iterable[str]] = None,
                      after_seq: Optional[int] = None,
                      due_before: Optional[float] = None,
                      limit: Optional[int] = None
                      ) -> List[Dict[str, Any]]:
        self.flush()
        return self.inner.load_messages(
            sub_id=sub_id, statuses=statuses, after_seq=after_seq,
            due_before=due_before, limit=limit)

    def count_messages(self, *, statuses: Optional[Iterable[str]] = None
                       ) -> int:
        self.flush()
        return self.inner.count_messages(statuses=statuses)

    # ----------------------------------------------------------- lifecycle
    def close(self) -> None:
        self._stop.set()
        flusher = self._flusher
        if flusher is not None:
            flusher.join(timeout=2.0)
        self.flush()
        self.inner.close()
