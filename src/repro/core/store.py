"""Durable state store for the iDDS head service (paper §2 catalogs).

The paper's iDDS anchors all orchestration state — requests, transforms,
collections, contents — in database-backed Restful catalogs so daemons
coordinate through shared state and the service survives restarts.  This
module is that persistence boundary for the reproduction:

  * :class:`Store`         — the narrow interface daemons journal through;
  * :class:`InMemoryStore` — dict-backed, zero overhead, no durability
                             (unit tests, simulators, benchmarks);
  * :class:`SqliteStore`   — stdlib ``sqlite3`` in WAL mode with one
                             connection per thread, so the six daemon
                             threads and the REST pool write concurrently.

Entities are journaled as JSON blobs keyed by their natural primary key,
with the columns needed for catalog queries (status filtering, pagination)
lifted out.  ``IDDS.recover()`` replays a store into a fresh head service
after a crash; see docs/architecture.md for the recovery semantics.
"""
from __future__ import annotations

import json
import sqlite3
import threading
from typing import Any, Dict, List, Optional, Tuple


class StoreError(Exception):
    """The backing file is unusable (corrupt, wrong format, locked away)."""


# Request catalog statuses a client may filter on (GET /requests?status=).
# "suspended"/"aborted" are entered via lifecycle commands (commands.py).
VALID_REQUEST_STATUSES = ("new", "accepted", "running", "suspended",
                          "finished", "failed", "aborted")

# Content rows only ever advance through the state machine (new ->
# staging -> available -> failed/delivered), but they are journaled from
# several threads (stager pool, daemon threads) whose point-in-time
# snapshots can commit out of order — a stager's "available" write
# queued behind the write lock must not clobber the "delivered" row the
# Transformer committed meanwhile.  Upserts therefore apply only when
# the incoming row does not REGRESS the stored rank (lost-update guard).
# "failed" ranks BELOW "available": failed -> available is the one legal
# backward transition (a hedge landing after the original request
# exhausted its attempts — live state takes the landing, so the journal
# must too), while available -> failed cannot happen (set_failed no-ops
# once a file is available).
_CONTENT_RANK = {"new": 0, "staging": 1, "failed": 2, "available": 3,
                 "delivered": 4}


def _content_rank(status: Optional[str]) -> int:
    return _CONTENT_RANK.get(status or "", 0)


class Store:
    """Journal + catalog for head-service state.

    ``save_*`` methods are upserts keyed on the entity's id and must be
    safe to call from any daemon thread.  ``load_*`` methods return
    plain dicts in insertion order — `recover()` reassembles the object
    graph from them.  Implementations must make ``save_works`` atomic:
    the Marshaller journals a terminated Work together with the
    successors its conditions spawned, and a crash must never persist
    one without the other (that is what makes recovery exactly-once).
    """

    # -- requests ---------------------------------------------------------
    def save_request(self, info: Dict[str, Any]) -> None:
        raise NotImplementedError

    def get_request(self, request_id: str) -> Optional[Dict[str, Any]]:
        raise NotImplementedError

    def list_requests(self, *, status: Optional[str] = None,
                      limit: Optional[int] = None,
                      offset: int = 0) -> List[Dict[str, Any]]:
        raise NotImplementedError

    def count_requests(self, *, status: Optional[str] = None) -> int:
        raise NotImplementedError

    # -- workflows (structure only; works journaled separately) -----------
    def save_workflow(self, wf: Dict[str, Any]) -> None:
        raise NotImplementedError

    def load_workflows(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- works -------------------------------------------------------------
    def save_works(self, workflow_id: str,
                   works: List[Dict[str, Any]]) -> None:
        """Upsert a batch of works atomically (all or none)."""
        raise NotImplementedError

    def save_work(self, workflow_id: str, work: Dict[str, Any]) -> None:
        self.save_works(workflow_id, [work])

    def load_works(self) -> List[Tuple[str, Dict[str, Any]]]:
        """Every persisted work as ``(workflow_id, work_dict)``."""
        raise NotImplementedError

    # -- processings --------------------------------------------------------
    def save_processing(self, proc: Dict[str, Any]) -> None:
        raise NotImplementedError

    def load_processings(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- leases (distributed execution plane) ------------------------------
    def save_lease(self, lease: Dict[str, Any]) -> None:
        """Upsert one lease row keyed on ``job_id`` (the scheduler
        journals grants and renewals so a head crash mid-lease can be
        audited and the lease requeued by ``recover()``)."""
        raise NotImplementedError

    def delete_lease(self, job_id: str) -> None:
        raise NotImplementedError

    def load_leases(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- lifecycle commands (steering plane) -------------------------------
    def save_command(self, cmd: Dict[str, Any]) -> None:
        """Upsert one command row keyed on ``command_id``.  Commands are
        journaled ``pending`` before they are announced and ``done``/
        ``failed`` after they apply, so ``recover()`` can replay the
        in-flight ones exactly once."""
        raise NotImplementedError

    def load_commands(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- collections + contents --------------------------------------------
    def save_collection(self, coll: Dict[str, Any]) -> None:
        """Upsert a collection and its per-file contents."""
        raise NotImplementedError

    def save_contents(self, collection: str,
                      files: List[Dict[str, Any]]) -> None:
        """Upsert only the given content rows (a full ``save_collection``
        rewrite is O(files); state transitions touch one file at a
        time)."""
        raise NotImplementedError

    def load_collections(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- consumer subscriptions (delivery plane) ---------------------------
    def save_subscription(self, sub: Dict[str, Any]) -> None:
        """Upsert one subscription row keyed on ``sub_id``; the row
        embeds the subscription's delivery records, so the Conductor
        journals every delivery transition through this call."""
        raise NotImplementedError

    def load_subscriptions(self) -> List[Dict[str, Any]]:
        raise NotImplementedError

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# In-memory (no durability; the pre-PR behaviour, now behind the interface)
# ---------------------------------------------------------------------------


class InMemoryStore(Store):
    """Dict-backed store: same journaling surface, nothing survives the
    process.  Keeps the hot path allocation-cheap for simulators and the
    in-memory arm of ``benchmarks/store_bench.py``."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._requests: Dict[str, Dict[str, Any]] = {}
        self._workflows: Dict[str, Dict[str, Any]] = {}
        self._works: Dict[str, Tuple[str, Dict[str, Any]]] = {}
        self._processings: Dict[str, Dict[str, Any]] = {}
        self._collections: Dict[str, Dict[str, Any]] = {}
        self._leases: Dict[str, Dict[str, Any]] = {}
        self._commands: Dict[str, Dict[str, Any]] = {}
        self._subscriptions: Dict[str, Dict[str, Any]] = {}

    def save_request(self, info: Dict[str, Any]) -> None:
        with self._lock:
            self._requests[info["request_id"]] = dict(info)

    def get_request(self, request_id: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            info = self._requests.get(request_id)
            return dict(info) if info is not None else None

    def list_requests(self, *, status: Optional[str] = None,
                      limit: Optional[int] = None,
                      offset: int = 0) -> List[Dict[str, Any]]:
        with self._lock:
            rows = [dict(r) for r in self._requests.values()
                    if status is None or r.get("status") == status]
        end = None if limit is None else offset + limit
        return rows[offset:end]

    def count_requests(self, *, status: Optional[str] = None) -> int:
        with self._lock:
            return sum(1 for r in self._requests.values()
                       if status is None or r.get("status") == status)

    def save_workflow(self, wf: Dict[str, Any]) -> None:
        with self._lock:
            self._workflows[wf["workflow_id"]] = dict(wf)

    def load_workflows(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(w) for w in self._workflows.values()]

    def save_works(self, workflow_id: str,
                   works: List[Dict[str, Any]]) -> None:
        with self._lock:
            for w in works:
                self._works[w["work_id"]] = (workflow_id, dict(w))

    def load_works(self) -> List[Tuple[str, Dict[str, Any]]]:
        with self._lock:
            return [(wf_id, dict(w))
                    for wf_id, w in self._works.values()]

    def save_processing(self, proc: Dict[str, Any]) -> None:
        with self._lock:
            self._processings[proc["proc_id"]] = dict(proc)

    def load_processings(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(p) for p in self._processings.values()]

    def save_lease(self, lease: Dict[str, Any]) -> None:
        with self._lock:
            self._leases[lease["job_id"]] = dict(lease)

    def delete_lease(self, job_id: str) -> None:
        with self._lock:
            self._leases.pop(job_id, None)

    def load_leases(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(le) for le in self._leases.values()]

    def save_command(self, cmd: Dict[str, Any]) -> None:
        with self._lock:
            self._commands[cmd["command_id"]] = dict(cmd)

    def load_commands(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [dict(c) for c in self._commands.values()]

    def _merge_contents(self, coll: Dict[str, Any],
                        files: List[Dict[str, Any]]) -> None:
        index = {f["name"]: i for i, f in enumerate(coll["files"])}
        for f in files:
            f = json.loads(json.dumps(f))
            i = index.get(f["name"])
            if i is None:
                index[f["name"]] = len(coll["files"])
                coll["files"].append(f)
            elif (_content_rank(f.get("status"))
                  >= _content_rank(coll["files"][i].get("status"))):
                coll["files"][i] = f

    def save_collection(self, coll: Dict[str, Any]) -> None:
        with self._lock:
            existing = self._collections.setdefault(
                coll["name"], {"name": coll["name"],
                               "scope": coll.get("scope", "idds"),
                               "files": []})
            existing["scope"] = coll.get("scope", "idds")
            self._merge_contents(existing, coll.get("files", []))

    def save_contents(self, collection: str,
                      files: List[Dict[str, Any]]) -> None:
        with self._lock:
            coll = self._collections.setdefault(
                collection, {"name": collection, "scope": "idds",
                             "files": []})
            self._merge_contents(coll, files)

    def load_collections(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [json.loads(json.dumps(c))
                    for c in self._collections.values()]

    def save_subscription(self, sub: Dict[str, Any]) -> None:
        with self._lock:
            self._subscriptions[sub["sub_id"]] = json.loads(json.dumps(sub))

    def load_subscriptions(self) -> List[Dict[str, Any]]:
        with self._lock:
            return [json.loads(json.dumps(s))
                    for s in self._subscriptions.values()]


# ---------------------------------------------------------------------------
# SQLite (WAL mode, one connection per thread)
# ---------------------------------------------------------------------------


_SCHEMA = """
CREATE TABLE IF NOT EXISTS requests (
    request_id   TEXT PRIMARY KEY,
    workflow_id  TEXT,
    requester    TEXT,
    status       TEXT,
    submitted_at REAL,
    data         TEXT NOT NULL,
    seq          INTEGER
);
CREATE INDEX IF NOT EXISTS idx_requests_status ON requests (status);
CREATE TABLE IF NOT EXISTS workflows (
    workflow_id TEXT PRIMARY KEY,
    name        TEXT,
    data        TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS works (
    work_id     TEXT PRIMARY KEY,
    workflow_id TEXT,
    status      TEXT,
    data        TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_works_workflow ON works (workflow_id);
CREATE TABLE IF NOT EXISTS processings (
    proc_id TEXT PRIMARY KEY,
    work_id TEXT,
    status  TEXT,
    data    TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_processings_work ON processings (work_id);
CREATE TABLE IF NOT EXISTS leases (
    job_id     TEXT PRIMARY KEY,
    worker_id  TEXT,
    queue      TEXT,
    expires_at REAL,
    data       TEXT NOT NULL
);
CREATE TABLE IF NOT EXISTS commands (
    command_id TEXT PRIMARY KEY,
    request_id TEXT,
    action     TEXT,
    status     TEXT,
    created_at REAL,
    data       TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_commands_request ON commands (request_id);
CREATE TABLE IF NOT EXISTS collections (
    name  TEXT PRIMARY KEY,
    scope TEXT
);
CREATE TABLE IF NOT EXISTS contents (
    collection TEXT,
    name       TEXT,
    size       INTEGER,
    available  INTEGER,
    processed  INTEGER,
    status     TEXT,
    created_at REAL,
    updated_at REAL,
    PRIMARY KEY (collection, name)
);
CREATE TABLE IF NOT EXISTS subscriptions (
    sub_id   TEXT PRIMARY KEY,
    consumer TEXT,
    data     TEXT NOT NULL
);
"""

# columns added to `contents` after the table first shipped: pre-existing
# store files are migrated in place on open (ALTER TABLE ADD COLUMN)
_CONTENTS_MIGRATIONS = (("status", "TEXT"), ("created_at", "REAL"),
                        ("updated_at", "REAL"))


class SqliteStore(Store):
    """Single-file durable store.

    WAL journal mode lets daemon threads write while REST threads read;
    ``synchronous=NORMAL`` bounds fsync cost to WAL checkpoints (the
    store journals ~10 small rows per workflow — FULL would fsync each).
    sqlite3 connections are not thread-safe, so each thread lazily opens
    its own (`threading.local`); all of them are closed by ``close()``.
    """

    def __init__(self, path: str):
        self.path = path
        self._local = threading.local()
        self._all_conns: List[sqlite3.Connection] = []
        self._conns_lock = threading.Lock()
        # validate the file up front: recover() must fail loudly on a
        # corrupt store, not silently return an empty catalog
        conn = self._conn()
        try:
            conn.execute("SELECT count(*) FROM requests").fetchone()
        except sqlite3.DatabaseError as e:  # pragma: no cover - re-raise
            raise StoreError(f"unusable store file {path!r}: {e}") from e

    def _conn(self) -> sqlite3.Connection:
        conn = getattr(self._local, "conn", None)
        if conn is not None:
            return conn
        try:
            # check_same_thread=False: each connection is only USED by
            # its owning thread while live, but close() must be able to
            # reap them all from whichever thread tears the store down
            conn = sqlite3.connect(self.path, timeout=30.0,
                                   isolation_level=None,
                                   check_same_thread=False)
            conn.execute("PRAGMA journal_mode=WAL")
            conn.execute("PRAGMA synchronous=NORMAL")
            conn.executescript(_SCHEMA)
            have = {r[1] for r in
                    conn.execute("PRAGMA table_info(contents)")}
            for col, decl in _CONTENTS_MIGRATIONS:
                if col not in have:
                    conn.execute(
                        f"ALTER TABLE contents ADD COLUMN {col} {decl}")
            # after the migration: the column exists on every schema
            conn.execute("CREATE INDEX IF NOT EXISTS idx_contents_status"
                         " ON contents (collection, status)")
        except sqlite3.DatabaseError as e:
            raise StoreError(
                f"unusable store file {self.path!r}: {e}") from e
        self._local.conn = conn
        with self._conns_lock:
            self._all_conns.append(conn)
        return conn

    # -- requests ---------------------------------------------------------
    def save_request(self, info: Dict[str, Any]) -> None:
        self._conn().execute(
            "INSERT INTO requests (request_id, workflow_id, requester,"
            " status, submitted_at, data, seq) VALUES (?, ?, ?, ?, ?, ?,"
            " (SELECT COALESCE(MAX(seq), 0) + 1 FROM requests))"
            " ON CONFLICT(request_id) DO UPDATE SET"
            " status=excluded.status, data=excluded.data",
            (info["request_id"], info.get("workflow_id"),
             info.get("requester"), info.get("status"),
             info.get("submitted_at"), json.dumps(info)))

    def get_request(self, request_id: str) -> Optional[Dict[str, Any]]:
        row = self._conn().execute(
            "SELECT data FROM requests WHERE request_id = ?",
            (request_id,)).fetchone()
        return json.loads(row[0]) if row else None

    def list_requests(self, *, status: Optional[str] = None,
                      limit: Optional[int] = None,
                      offset: int = 0) -> List[Dict[str, Any]]:
        sql = "SELECT data FROM requests"
        args: List[Any] = []
        if status is not None:
            sql += " WHERE status = ?"
            args.append(status)
        # LIMIT is required before OFFSET in sqlite; -1 means unbounded
        sql += " ORDER BY seq LIMIT ? OFFSET ?"
        args += [-1 if limit is None else limit, offset]
        rows = self._conn().execute(sql, args).fetchall()
        return [json.loads(r[0]) for r in rows]

    def count_requests(self, *, status: Optional[str] = None) -> int:
        if status is None:
            row = self._conn().execute(
                "SELECT count(*) FROM requests").fetchone()
        else:
            row = self._conn().execute(
                "SELECT count(*) FROM requests WHERE status = ?",
                (status,)).fetchone()
        return int(row[0])

    # -- workflows ---------------------------------------------------------
    def save_workflow(self, wf: Dict[str, Any]) -> None:
        self._conn().execute(
            "INSERT INTO workflows (workflow_id, name, data)"
            " VALUES (?, ?, ?) ON CONFLICT(workflow_id) DO UPDATE SET"
            " data=excluded.data",
            (wf["workflow_id"], wf.get("name"), json.dumps(wf)))

    def load_workflows(self) -> List[Dict[str, Any]]:
        rows = self._conn().execute(
            "SELECT data FROM workflows ORDER BY rowid").fetchall()
        return [json.loads(r[0]) for r in rows]

    # -- works -------------------------------------------------------------
    def save_works(self, workflow_id: str,
                   works: List[Dict[str, Any]]) -> None:
        if not works:
            return
        conn = self._conn()
        conn.execute("BEGIN IMMEDIATE")
        try:
            conn.executemany(
                "INSERT INTO works (work_id, workflow_id, status, data)"
                " VALUES (?, ?, ?, ?) ON CONFLICT(work_id) DO UPDATE SET"
                " status=excluded.status, data=excluded.data",
                [(w["work_id"], workflow_id, w.get("status"),
                  json.dumps(w)) for w in works])
            conn.execute("COMMIT")
        except BaseException:
            conn.execute("ROLLBACK")
            raise

    def load_works(self) -> List[Tuple[str, Dict[str, Any]]]:
        rows = self._conn().execute(
            "SELECT workflow_id, data FROM works ORDER BY rowid").fetchall()
        return [(r[0], json.loads(r[1])) for r in rows]

    # -- processings --------------------------------------------------------
    def save_processing(self, proc: Dict[str, Any]) -> None:
        self._conn().execute(
            "INSERT INTO processings (proc_id, work_id, status, data)"
            " VALUES (?, ?, ?, ?) ON CONFLICT(proc_id) DO UPDATE SET"
            " status=excluded.status, data=excluded.data",
            (proc["proc_id"], proc.get("work_id"), proc.get("status"),
             json.dumps(proc)))

    def load_processings(self) -> List[Dict[str, Any]]:
        rows = self._conn().execute(
            "SELECT data FROM processings ORDER BY rowid").fetchall()
        return [json.loads(r[0]) for r in rows]

    # -- leases --------------------------------------------------------------
    def save_lease(self, lease: Dict[str, Any]) -> None:
        self._conn().execute(
            "INSERT INTO leases (job_id, worker_id, queue, expires_at,"
            " data) VALUES (?, ?, ?, ?, ?)"
            " ON CONFLICT(job_id) DO UPDATE SET"
            " worker_id=excluded.worker_id, expires_at=excluded.expires_at,"
            " data=excluded.data",
            (lease["job_id"], lease.get("worker_id"), lease.get("queue"),
             lease.get("expires_at"), json.dumps(lease)))

    def delete_lease(self, job_id: str) -> None:
        self._conn().execute("DELETE FROM leases WHERE job_id = ?",
                             (job_id,))

    def load_leases(self) -> List[Dict[str, Any]]:
        rows = self._conn().execute(
            "SELECT data FROM leases ORDER BY rowid").fetchall()
        return [json.loads(r[0]) for r in rows]

    # -- commands ------------------------------------------------------------
    def save_command(self, cmd: Dict[str, Any]) -> None:
        self._conn().execute(
            "INSERT INTO commands (command_id, request_id, action,"
            " status, created_at, data) VALUES (?, ?, ?, ?, ?, ?)"
            " ON CONFLICT(command_id) DO UPDATE SET"
            " status=excluded.status, data=excluded.data",
            (cmd["command_id"], cmd.get("request_id"), cmd.get("action"),
             cmd.get("status"), cmd.get("created_at"), json.dumps(cmd)))

    def load_commands(self) -> List[Dict[str, Any]]:
        rows = self._conn().execute(
            "SELECT data FROM commands ORDER BY rowid").fetchall()
        return [json.loads(r[0]) for r in rows]

    # -- collections --------------------------------------------------------
    _RANK_SQL = ("CASE IFNULL({col}, '') WHEN 'staging' THEN 1"
                 " WHEN 'failed' THEN 2 WHEN 'available' THEN 3"
                 " WHEN 'delivered' THEN 4 ELSE 0 END")
    # the WHERE clause is the lost-update guard: see _CONTENT_RANK
    _CONTENT_UPSERT = (
        "INSERT INTO contents (collection, name, size, available,"
        " processed, status, created_at, updated_at)"
        " VALUES (?, ?, ?, ?, ?, ?, ?, ?)"
        " ON CONFLICT(collection, name) DO UPDATE SET"
        " size=excluded.size, available=excluded.available,"
        " processed=excluded.processed, status=excluded.status,"
        " created_at=excluded.created_at, updated_at=excluded.updated_at"
        " WHERE " + _RANK_SQL.format(col="excluded.status")
        + " >= " + _RANK_SQL.format(col="contents.status"))

    @staticmethod
    def _content_row(collection: str, f: Dict[str, Any]) -> Tuple:
        return (collection, f["name"], f.get("size", 0),
                int(bool(f.get("available"))),
                int(bool(f.get("processed"))), f.get("status"),
                f.get("created_at"), f.get("updated_at"))

    def save_collection(self, coll: Dict[str, Any]) -> None:
        conn = self._conn()
        conn.execute("BEGIN IMMEDIATE")
        try:
            conn.execute(
                "INSERT INTO collections (name, scope) VALUES (?, ?)"
                " ON CONFLICT(name) DO UPDATE SET scope=excluded.scope",
                (coll["name"], coll.get("scope", "idds")))
            conn.executemany(
                self._CONTENT_UPSERT,
                [self._content_row(coll["name"], f)
                 for f in coll.get("files", [])])
            conn.execute("COMMIT")
        except BaseException:
            conn.execute("ROLLBACK")
            raise

    def save_contents(self, collection: str,
                      files: List[Dict[str, Any]]) -> None:
        if not files:
            return
        conn = self._conn()
        conn.execute("BEGIN IMMEDIATE")
        try:
            conn.execute(
                "INSERT OR IGNORE INTO collections (name, scope)"
                " VALUES (?, 'idds')", (collection,))
            conn.executemany(
                self._CONTENT_UPSERT,
                [self._content_row(collection, f) for f in files])
            conn.execute("COMMIT")
        except BaseException:
            conn.execute("ROLLBACK")
            raise

    def load_collections(self) -> List[Dict[str, Any]]:
        conn = self._conn()
        colls = conn.execute(
            "SELECT name, scope FROM collections ORDER BY rowid").fetchall()
        out = []
        for name, scope in colls:
            files = conn.execute(
                "SELECT name, size, available, processed, status,"
                " created_at, updated_at FROM contents"
                " WHERE collection = ? ORDER BY rowid", (name,)).fetchall()
            out.append({"name": name, "scope": scope,
                        "files": [{"name": f[0], "size": f[1],
                                   "available": bool(f[2]),
                                   "processed": bool(f[3]),
                                   "status": f[4],
                                   "created_at": f[5],
                                   "updated_at": f[6]}
                                  for f in files]})
        return out

    # -- subscriptions -------------------------------------------------------
    def save_subscription(self, sub: Dict[str, Any]) -> None:
        self._conn().execute(
            "INSERT INTO subscriptions (sub_id, consumer, data)"
            " VALUES (?, ?, ?) ON CONFLICT(sub_id) DO UPDATE SET"
            " data=excluded.data",
            (sub["sub_id"], sub.get("consumer"), json.dumps(sub)))

    def load_subscriptions(self) -> List[Dict[str, Any]]:
        rows = self._conn().execute(
            "SELECT data FROM subscriptions ORDER BY rowid").fetchall()
        return [json.loads(r[0]) for r in rows]

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        with self._conns_lock:
            conns, self._all_conns = self._all_conns, []
        for conn in conns:
            try:
                conn.close()
            except sqlite3.Error:  # pragma: no cover - best effort
                pass
        self._local = threading.local()
