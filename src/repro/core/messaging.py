"""Pluggable message bus (the paper's ActiveMQ boundary).

Daemons never call each other directly — everything crosses the bus, so
a real deployment swaps the backend for an AMQP/STOMP client without
touching daemon logic.  Two backends ship here, selected via
``IDDS(bus=...)`` / ``repro.core.rest --bus``:

  * :class:`LocalBus`        — in-process deques + a condition variable;
                               zero overhead, single head only.  This is
                               the pre-multi-head ``MessageBus`` (the old
                               name stays importable).
  * :class:`StorePollingBus` — journals every message through the
                               store's ``bus_messages`` table, so a
                               second head's daemons wake on the first
                               head's announcements.  Work-queue topics
                               are consumed exactly once cluster-wide
                               (atomic per-row compare-and-set);
                               broadcast topics (collection updates,
                               consumer notifications) are cursor-read
                               by every head independently.

Both are thread-safe and expose the same surface: queue semantics
(publish/poll/wait/wait_any/depth), broadcast subscriptions
(Conductor -> consumer notifications), and ``requeue`` — redelivery of
a message a daemon consumed but cannot process because another live
head owns its workflow (see daemons.Context.try_own).
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Deque, Dict, Iterable, List, Optional


@dataclass(frozen=True)
class Message:
    topic: str
    body: Dict[str, Any]
    msg_id: int
    ts: float
    #: request-lifecycle correlation id (obs.new_trace_id); rides the
    #: bus so cross-head hops stitch into one trace
    trace_id: Optional[str] = None


class BusBackend:
    """The surface daemons program against.  ``poll``/``wait`` consume;
    ``wait_any`` only detects; ``subscribe`` registers a broadcast
    callback fired once per message (on the publishing head for local
    publishes, on the first fetching head for cross-head traffic)."""

    #: backend identifier surfaced in /v1/healthz and /v1/cluster
    name = "abstract"

    # -- telemetry (class attrs: unbound costs one attribute lookup) ----
    _obs_lag = None
    _obs_pub = None

    def bind_metrics(self, registry: Any) -> None:
        """Attach an ``obs.MetricsRegistry``: per-topic publish counts
        and publish->consume lag.  Lag is a wall-clock delta by design
        — the publisher may be another process (StorePollingBus), so
        monotonic clocks are not comparable.  Children are cached per
        topic so the publish hot path pays one dict lookup, not a
        ``labels()`` key build (worst case a racing first use resolves
        the same child twice — the family dedupes under its lock)."""
        self._obs_lag = registry.histogram(
            "bus_lag_seconds", "publish->consume lag", labels=("topic",))
        self._obs_pub = registry.counter(
            "bus_published_total", "messages published",
            labels=("topic",))
        self._lag_children: Dict[str, Any] = {}
        self._pub_children: Dict[str, Any] = {}

    def _pub_child(self, topic: str):
        child = self._pub_children.get(topic)
        if child is None:
            child = self._pub_children[topic] = self._obs_pub.labels(
                topic=topic)
        return child

    def _observe_lag(self, topic: str, msgs: List[Message]) -> None:
        if self._obs_lag is None or not msgs:
            return
        child = self._lag_children.get(topic)
        if child is None:
            child = self._lag_children[topic] = self._obs_lag.labels(
                topic=topic)
        now = time.time()
        for m in msgs:
            child.observe(max(now - m.ts, 0.0))

    def publish(self, topic: str, body: Dict[str, Any],
                trace_id: Optional[str] = None) -> Message:
        raise NotImplementedError

    def requeue(self, msg: Message) -> None:
        """Put a consumed message back for redelivery (possibly to
        another head).  Not counted in ``published``; never re-fires
        broadcast subscribers."""
        raise NotImplementedError

    def poll(self, topic: str, max_n: int = 0) -> List[Message]:
        raise NotImplementedError

    def wait(self, topic: str, timeout: float = 1.0) -> Optional[Message]:
        raise NotImplementedError

    def wait_any(self, topics: Iterable[str],
                 timeout: float = 1.0) -> bool:
        raise NotImplementedError

    def depth(self, topic: str) -> int:
        raise NotImplementedError

    def subscribe(self, topic: str,
                  callback: Callable[[Message], None]) -> None:
        raise NotImplementedError


class LocalBus(BusBackend):
    name = "local"

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._queues: Dict[str, Deque[Message]] = collections.defaultdict(
            collections.deque)
        self._subs: Dict[str, List[Callable[[Message], None]]] = (
            collections.defaultdict(list))
        self._ids = itertools.count()
        self._cv = threading.Condition(self._lock)
        self.published = 0

    # -- queue semantics ----------------------------------------------------
    def publish(self, topic: str, body: Dict[str, Any],
                trace_id: Optional[str] = None) -> Message:
        with self._cv:
            msg = Message(topic, dict(body), next(self._ids), time.time(),
                          trace_id)
            self._queues[topic].append(msg)
            self.published += 1
            if self._obs_pub is not None:
                self._pub_child(topic).inc()
            for cb in self._subs.get(topic, ()):  # broadcast listeners
                cb(msg)
            self._cv.notify_all()
            return msg

    def requeue(self, msg: Message) -> None:
        # single-process: the only consumers are this head's daemons, so
        # a plain re-append suffices (no backoff, no subscriber re-fire)
        with self._cv:
            self._queues[msg.topic].append(msg)
            self._cv.notify_all()

    def poll(self, topic: str, max_n: int = 0) -> List[Message]:
        """Consume up to max_n messages (0 = drain)."""
        with self._lock:
            q = self._queues[topic]
            n = len(q) if max_n <= 0 else min(max_n, len(q))
            msgs = [q.popleft() for _ in range(n)]
        self._observe_lag(topic, msgs)
        return msgs

    def wait(self, topic: str, timeout: float = 1.0) -> Optional[Message]:
        """Blocking consume: pop one message, waiting up to ``timeout``
        for a publish (condition-based — no sleep-and-poll).  Deadlines
        use the monotonic clock: an NTP step must neither stall nor
        prematurely expire a daemon's idle-wait."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while not self._queues[topic]:
                rem = deadline - time.monotonic()
                if rem <= 0:
                    return None
                self._cv.wait(rem)
            msg = self._queues[topic].popleft()
        self._observe_lag(topic, [msg])
        return msg

    def wait_any(self, topics: Iterable[str], timeout: float = 1.0) -> bool:
        """Block until at least one of ``topics`` has a queued message
        (True) or ``timeout`` elapses (False).  Consumes nothing — the
        daemon loops that idle on this then drain via ``poll``."""
        topics = tuple(topics)
        deadline = time.monotonic() + timeout
        with self._cv:
            while not any(self._queues[t] for t in topics):
                rem = deadline - time.monotonic()
                if rem <= 0:
                    return False
                self._cv.wait(rem)
            return True

    def depth(self, topic: str) -> int:
        with self._lock:
            return len(self._queues[topic])

    # -- broadcast semantics --------------------------------------------------
    def subscribe(self, topic: str,
                  callback: Callable[[Message], None]) -> None:
        with self._lock:
            self._subs[topic].append(callback)


# the pre-multi-head name; external code may still instantiate it
MessageBus = LocalBus


class StorePollingBus(BusBackend):
    """Store-backed bus: publishes journal into ``bus_messages`` and
    consumption is a poll against the shared store, so every head in
    the cluster sees every announcement.

    Delivery is at-least-once per topic class: queue topics are taken
    exactly once cluster-wide (per-row compare-and-set in the store);
    broadcast topics advance a per-head in-memory cursor initialised at
    the journal's high-water mark on boot — a freshly started head does
    not replay historical broadcasts, because ``recover()`` already
    rebuilds that state from the catalogs.

    ``wait``/``wait_any`` are sleep-polls at ``poll_interval`` (there is
    no cross-process condition variable over SQLite); the interval
    bounds cross-head wake latency.
    """

    name = "store"

    def __init__(self, store: Any, head_id: str, *,
                 poll_interval: float = 0.02,
                 requeue_delay: float = 0.05) -> None:
        self.store = store
        self.head_id = head_id
        self.poll_interval = float(poll_interval)
        self.requeue_delay = float(requeue_delay)
        self._lock = threading.RLock()
        self._subs: Dict[str, List[Callable[[Message], None]]] = (
            collections.defaultdict(list))
        self._cursors: Dict[str, int] = dict.fromkeys(
            BROADCAST_TOPICS, store.bus_max_id())
        self.published = 0

    # -- queue semantics ----------------------------------------------------
    # The store's fetch verbs return only (msg_id, topic, body, origin),
    # so publish metadata that must survive the journal hop — the
    # publish wall-time (lag measurement) and the trace_id — rides
    # INSIDE the body under reserved keys, stripped again on fetch.
    _PUB_TS_KEY = "_pub_ts"
    _TRACE_KEY = "_trace_id"

    def publish(self, topic: str, body: Dict[str, Any],
                trace_id: Optional[str] = None) -> Message:
        now = time.time()
        journaled = dict(body)
        journaled[self._PUB_TS_KEY] = now
        if trace_id is not None:
            journaled[self._TRACE_KEY] = trace_id
        msg_id = self.store.bus_publish(topic, journaled,
                                        origin=self.head_id)
        msg = Message(topic, dict(body), msg_id, now, trace_id)
        self.published += 1
        if self._obs_pub is not None:
            self._pub_child(topic).inc()
        # local subscribers fire at publish time (LocalBus parity);
        # other heads fire theirs when they first fetch the row —
        # origin-keyed so nobody fires twice
        with self._lock:
            subs = tuple(self._subs.get(topic, ()))
        for cb in subs:
            cb(msg)
        return msg

    def requeue(self, msg: Message) -> None:
        # not_before pushes redelivery past the next poll tick so the
        # requeueing head does not busy-spin re-consuming a message it
        # already knows it cannot process.  Original publish time and
        # trace_id are preserved: redelivery extends the same hop.
        journaled = dict(msg.body)
        journaled[self._PUB_TS_KEY] = msg.ts
        if msg.trace_id is not None:
            journaled[self._TRACE_KEY] = msg.trace_id
        self.store.bus_publish(msg.topic, journaled,
                               origin=self.head_id,
                               not_before=time.time()
                               + self.requeue_delay)

    def _to_messages(self, rows: List[Dict[str, Any]],
                     topic: str) -> List[Message]:
        msgs = []
        with self._lock:
            subs = tuple(self._subs.get(topic, ()))
        for r in rows:
            body = r["body"]
            pub_ts = body.pop(self._PUB_TS_KEY, None)
            trace_id = body.pop(self._TRACE_KEY, None)
            m = Message(r["topic"], body, r["msg_id"],
                        pub_ts if pub_ts is not None else time.time(),
                        trace_id)
            msgs.append(m)
            if subs and r.get("origin") != self.head_id:
                for cb in subs:
                    cb(m)
        self._observe_lag(topic, msgs)
        return msgs

    def poll(self, topic: str, max_n: int = 0) -> List[Message]:
        if topic in BROADCAST_TOPICS:
            with self._lock:
                cursor = self._cursors.get(topic, 0)
                rows = self.store.bus_fetch_after([topic], cursor,
                                                  max_n=max_n)
                if rows:
                    self._cursors[topic] = rows[-1]["msg_id"]
        else:
            rows = self.store.bus_consume([topic], self.head_id,
                                          max_n=max_n)
        return self._to_messages(rows, topic)

    def wait(self, topic: str, timeout: float = 1.0) -> Optional[Message]:
        deadline = time.monotonic() + timeout
        while True:
            msgs = self.poll(topic, max_n=1)
            if msgs:
                return msgs[0]
            rem = deadline - time.monotonic()
            if rem <= 0:
                return None
            time.sleep(min(self.poll_interval, rem))

    def _available(self, topics: Iterable[str]) -> bool:
        queue_topics = []
        for t in topics:
            if t in BROADCAST_TOPICS:
                with self._lock:
                    cursor = self._cursors.get(t, 0)
                if self.store.bus_fetch_after([t], cursor, max_n=1):
                    return True
            else:
                queue_topics.append(t)
        return bool(queue_topics
                    and self.store.bus_depth(queue_topics) > 0)

    def wait_any(self, topics: Iterable[str],
                 timeout: float = 1.0) -> bool:
        topics = tuple(topics)
        deadline = time.monotonic() + timeout
        while True:
            if self._available(topics):
                return True
            rem = deadline - time.monotonic()
            if rem <= 0:
                return False
            time.sleep(min(self.poll_interval, rem))

    def depth(self, topic: str) -> int:
        if topic in BROADCAST_TOPICS:
            with self._lock:
                cursor = self._cursors.get(topic, 0)
            return len(self.store.bus_fetch_after([topic], cursor))
        return self.store.bus_depth([topic])

    # -- broadcast semantics --------------------------------------------------
    def subscribe(self, topic: str,
                  callback: Callable[[Message], None]) -> None:
        with self._lock:
            self._subs[topic].append(callback)

    # -- maintenance ---------------------------------------------------------
    def prune(self, retention_s: float = 300.0) -> int:
        """Drop journal rows older than ``retention_s`` (consumed or
        broadcast-read; the Watchdog calls this periodically so the
        table does not grow without bound)."""
        return self.store.bus_prune(time.time() - retention_s)


def make_bus(kind: str, *, store: Any = None,
             head_id: str = "head") -> BusBackend:
    """Factory behind ``--bus local|store`` / ``IDDS(bus=...)``."""
    if kind == "local":
        return LocalBus()
    if kind == "store":
        if store is None:
            raise ValueError("bus 'store' requires a store")
        return StorePollingBus(store, head_id)
    raise ValueError(f"unknown bus backend {kind!r}"
                     " (expected 'local' or 'store')")


# Canonical topic names (Fig. 1 arrows)
T_NEW_REQUESTS = "idds.requests.new"          # client -> Clerk
T_NEW_WORKFLOWS = "idds.workflows.new"        # Clerk -> Marshaller
T_NEW_WORKS = "idds.works.new"                # Marshaller -> Transformer
T_NEW_PROCESSINGS = "idds.processings.new"    # Transformer -> Carrier
T_PROCESSING_DONE = "idds.processings.done"  # Carrier -> Transf./Marshaller
T_WORK_DONE = "idds.works.done"               # Transformer -> Marshaller
T_OUTPUT_AVAILABLE = "idds.outputs.available"  # Transformer -> Conductor
T_CONSUMER_NOTIFY = "idds.consumers.notify"   # Conductor -> data consumers
# Advisory "outbox has rows" wake, Conductor -> Publisher.  Queue
# semantics on purpose: exactly one head's Publisher needs to wake, and
# losing the wake is harmless — the Publisher also drains by store
# query, so the message is a latency optimization, not the delivery
# mechanism.
T_OUTBOX = "idds.outbox.new"
T_COLLECTION_UPDATED = "ddm.collections.updated"  # DDM -> Transformer
# steering plane (request lifecycle commands)
T_NEW_COMMANDS = "idds.commands.new"              # client -> Commander
T_CMD_TRANSFORMER = "idds.commands.transformer"   # Commander -> Transformer
T_CMD_CARRIER = "idds.commands.carrier"           # Commander -> Carrier

# Topics every head must observe rather than any one head consume: a
# collection-availability event or consumer notification is relevant to
# whichever head owns the interested workflow (or to an external
# consumer), so queue semantics would let the wrong head swallow it.
BROADCAST_TOPICS = frozenset({T_COLLECTION_UPDATED, T_CONSUMER_NOTIFY})
