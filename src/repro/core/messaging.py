"""In-process message bus (the paper's ActiveMQ boundary).

Daemons never call each other directly — everything crosses the bus, so a
real deployment swaps this class for an AMQP/STOMP client without touching
daemon logic.  Thread-safe; supports both queue semantics (each message
consumed once, round-robin across consumers of a topic) and broadcast
subscriptions (Conductor -> consumer notifications).
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Deque, Dict, Iterable, List, Optional


@dataclass(frozen=True)
class Message:
    topic: str
    body: Dict[str, Any]
    msg_id: int
    ts: float


class MessageBus:
    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._queues: Dict[str, Deque[Message]] = collections.defaultdict(
            collections.deque)
        self._subs: Dict[str, List[Callable[[Message], None]]] = (
            collections.defaultdict(list))
        self._ids = itertools.count()
        self._cv = threading.Condition(self._lock)
        self.published = 0

    # -- queue semantics ----------------------------------------------------
    def publish(self, topic: str, body: Dict[str, Any]) -> Message:
        with self._cv:
            msg = Message(topic, dict(body), next(self._ids), time.time())
            self._queues[topic].append(msg)
            self.published += 1
            for cb in self._subs.get(topic, ()):  # broadcast listeners
                cb(msg)
            self._cv.notify_all()
            return msg

    def poll(self, topic: str, max_n: int = 0) -> List[Message]:
        """Consume up to max_n messages (0 = drain)."""
        with self._lock:
            q = self._queues[topic]
            n = len(q) if max_n <= 0 else min(max_n, len(q))
            return [q.popleft() for _ in range(n)]

    def wait(self, topic: str, timeout: float = 1.0) -> Optional[Message]:
        """Blocking consume: pop one message, waiting up to ``timeout``
        for a publish (condition-based — no sleep-and-poll).  Deadlines
        use the monotonic clock: an NTP step must neither stall nor
        prematurely expire a daemon's idle-wait."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while not self._queues[topic]:
                rem = deadline - time.monotonic()
                if rem <= 0:
                    return None
                self._cv.wait(rem)
            return self._queues[topic].popleft()

    def wait_any(self, topics: Iterable[str], timeout: float = 1.0) -> bool:
        """Block until at least one of ``topics`` has a queued message
        (True) or ``timeout`` elapses (False).  Consumes nothing — the
        daemon loops that idle on this then drain via ``poll``."""
        topics = tuple(topics)
        deadline = time.monotonic() + timeout
        with self._cv:
            while not any(self._queues[t] for t in topics):
                rem = deadline - time.monotonic()
                if rem <= 0:
                    return False
                self._cv.wait(rem)
            return True

    def depth(self, topic: str) -> int:
        with self._lock:
            return len(self._queues[topic])

    # -- broadcast semantics --------------------------------------------------
    def subscribe(self, topic: str,
                  callback: Callable[[Message], None]) -> None:
        with self._lock:
            self._subs[topic].append(callback)


# Canonical topic names (Fig. 1 arrows)
T_NEW_REQUESTS = "idds.requests.new"          # client -> Clerk
T_NEW_WORKFLOWS = "idds.workflows.new"        # Clerk -> Marshaller
T_NEW_WORKS = "idds.works.new"                # Marshaller -> Transformer
T_NEW_PROCESSINGS = "idds.processings.new"    # Transformer -> Carrier
T_PROCESSING_DONE = "idds.processings.done"  # Carrier -> Transf./Marshaller
T_WORK_DONE = "idds.works.done"               # Transformer -> Marshaller
T_OUTPUT_AVAILABLE = "idds.outputs.available"  # Transformer -> Conductor
T_CONSUMER_NOTIFY = "idds.consumers.notify"   # Conductor -> data consumers
T_COLLECTION_UPDATED = "ddm.collections.updated"  # DDM -> Transformer
# steering plane (request lifecycle commands)
T_NEW_COMMANDS = "idds.commands.new"              # client -> Commander
T_CMD_TRANSFORMER = "idds.commands.transformer"   # Commander -> Transformer
T_CMD_CARRIER = "idds.commands.carrier"           # Commander -> Carrier
