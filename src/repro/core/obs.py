"""Telemetry plane: metrics registry, lifecycle tracing, logging.

The paper positions iDDS as the orchestrator that steers workflows from
*observed* behaviour; Rucio and the Event Streaming Service precursor
both treat per-subsystem metrics and end-to-end delivery monitoring as
load-bearing infrastructure.  This module is that layer for the
reproduction — dependency-free (stdlib only) and cheap enough to stay
on in every hot path:

  * :class:`MetricsRegistry` — Counter / Gauge / Histogram families
    with labeled series.  Histograms use fixed log-scale buckets (the
    1-2.5-5 decade ladder) so p50/p95/p99 can be estimated without
    storing samples.  ``render()`` emits Prometheus text exposition
    (``text/plain; version=0.0.4``); ``snapshot()`` emits a JSON-able
    dict a peer head can merge (``render_snapshots``) for the
    cluster-wide ``/v1/metrics?cluster=1`` view.  Every series carries
    a constant ``head`` label so multi-head aggregation never collides.
    ``enabled=False`` turns every instrument into a no-op child — the
    obs_bench overhead arm measures exactly this delta.
  * :class:`Tracer` — journals timestamped request-lifecycle events
    (``submitted``, ``workflow_started``, ``work_transforming``,
    ``job_leased`` ... ``delivery_acked``) through the
    :class:`~repro.core.store.Store` with head attribution, so
    ``GET /v1/requests/<id>/trace`` can reconstruct where a request
    spent its time even when the hops ran on different heads.  A
    ``trace_id`` minted at submit rides REST bodies and bus
    :class:`~repro.core.messaging.Message` metadata to stitch
    cross-head spans.
  * :func:`build_trace` — pure function pairing start/end events into
    named spans with durations (the trace endpoint's response body).
  * :func:`setup_logging` / :func:`get_logger` — stdlib ``logging``
    configuration with head_id-tagged records and an optional JSON
    formatter (``--log-json`` on the rest/worker CLIs).

Locking: one small lock per child series (an uncontended acquire is
~100ns); family/registry locks are taken only at series creation.
Timestamps: metric durations use the monotonic clock; trace events are
journaled with wall-clock ``ts`` so heads can compare them
cross-process (see scripts/check_monotonic.py for the enforced split).
"""
from __future__ import annotations

import collections
import json
import logging
import sys
import threading
import time
import uuid
from bisect import bisect_left, insort
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

# daemon rounds or store flushes slower than this log a warning
SLOW_OP_THRESHOLD_S = 1.0

# fixed log-scale bucket ladder (seconds): 100us .. 2min, then +Inf.
# Fixed (not per-histogram) so cluster-wide merges can sum bucket-wise.
BUCKETS: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 120.0)


# ---------------------------------------------------------------------------
# Children (one labeled series each)
# ---------------------------------------------------------------------------


class _CounterChild:
    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class _GaugeChild:
    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self.value -= n


class _HistogramChild:
    __slots__ = ("_lock", "counts", "sum", "count")

    def __init__(self):
        self._lock = threading.Lock()
        self.counts = [0] * (len(BUCKETS) + 1)  # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        # first bucket with bound >= v (C-speed; this is the hottest
        # instrument call in the tree)
        i = bisect_left(BUCKETS, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    def observe_many(self, vs: Iterable[float]) -> None:
        """Record a batch under ONE lock acquisition — the bulk verbs
        (complete_many and friends) accumulate per-item durations and
        flush them here, amortizing the lock and dispatch cost."""
        with self._lock:
            counts = self.counts
            s = 0.0
            n = 0
            for v in vs:
                counts[bisect_left(BUCKETS, v)] += 1
                s += v
                n += 1
            self.sum += s
            self.count += n

    def time(self) -> "_Timer":
        return _Timer(self)

    def percentile(self, q: float) -> float:
        """Estimate the q-th percentile (0..100) from the buckets by
        linear interpolation; the +Inf bucket clamps to the last finite
        bound."""
        with self._lock:
            counts = list(self.counts)
            total = self.count
        if total == 0:
            return 0.0
        rank = q / 100.0 * total
        cum = 0
        lo = 0.0
        for i, c in enumerate(counts):
            if cum + c >= rank:
                if i >= len(BUCKETS):
                    return BUCKETS[-1]
                hi = BUCKETS[i]
                frac = (rank - cum) / c if c else 0.0
                return lo + (hi - lo) * frac
            cum += c
            if i < len(BUCKETS):
                lo = BUCKETS[i]
        return BUCKETS[-1]

    def percentiles(self, qs: Iterable[float] = (50, 95, 99)
                    ) -> Dict[str, float]:
        return {f"p{int(q)}": self.percentile(q) for q in qs}


class RollingPercentile:
    """Exact percentile over a bounded sliding window.

    The bucketed histogram above trades accuracy for cluster-wide
    mergeability; this is its exact, non-mergeable sibling for
    in-process decisions (the stager's hedge median, the intelligence
    plane's learned staging p95).  A deque keeps arrival order while a
    parallel sorted list is maintained incrementally with bisect, so an
    observation is O(log n) search + memmove on a small window and a
    percentile read is O(1) — never a full re-sort per read.
    """

    __slots__ = ("_lock", "_window", "_sorted")

    def __init__(self, window: int = 512):
        if window <= 0:
            raise ValueError("window must be positive")
        self._lock = threading.Lock()
        self._window: collections.deque = collections.deque(maxlen=window)
        self._sorted: List[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            if len(self._window) == self._window.maxlen:
                # capture the value about to fall off the window and
                # remove exactly one copy of it from the sorted view
                evicted = self._window[0]
                del self._sorted[bisect_left(self._sorted, evicted)]
            self._window.append(v)
            insort(self._sorted, v)

    def __len__(self) -> int:
        with self._lock:
            return len(self._sorted)

    def percentile(self, q: float) -> Optional[float]:
        """The q-th percentile (0..100) by nearest rank, or None while
        the window is empty."""
        with self._lock:
            n = len(self._sorted)
            if n == 0:
                return None
            return self._sorted[min(n - 1, int(q / 100.0 * n))]

    def median(self) -> Optional[float]:
        """Upper median (matches ``sorted(w)[len(w) // 2]``)."""
        with self._lock:
            n = len(self._sorted)
            return self._sorted[n // 2] if n else None

    def values(self) -> List[float]:
        """Arrival-ordered snapshot of the current window."""
        with self._lock:
            return list(self._window)


class _Timer:
    __slots__ = ("_child", "_t0")

    def __init__(self, child):
        self._child = child

    def __enter__(self) -> "_Timer":
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc) -> None:
        self._child.observe(time.monotonic() - self._t0)


class _NoopChild:
    """Every instrument method as a no-op: what ``enabled=False`` hands
    out, and the baseline the obs_bench overhead arm compares against."""

    __slots__ = ()

    def inc(self, n: float = 1.0) -> None:
        pass

    def dec(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def observe_many(self, vs) -> None:
        pass

    def time(self) -> "_NoopTimer":
        return _NOOP_TIMER

    def percentile(self, q: float) -> float:
        return 0.0

    def percentiles(self, qs=(50, 95, 99)) -> Dict[str, float]:
        return {f"p{int(q)}": 0.0 for q in qs}


class _NoopTimer:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        pass


_NOOP_CHILD = _NoopChild()
_NOOP_TIMER = _NoopTimer()


# ---------------------------------------------------------------------------
# Families (one metric name, many labeled series)
# ---------------------------------------------------------------------------

_CHILD_CLS = {"counter": _CounterChild, "gauge": _GaugeChild,
              "histogram": _HistogramChild}


class _Family:
    def __init__(self, name: str, kind: str, help_: str,
                 label_names: Tuple[str, ...], enabled: bool):
        self.name = name
        self.kind = kind
        self.help = help_
        self.label_names = label_names
        self.enabled = enabled
        self._lock = threading.Lock()
        self._children: Dict[Tuple[str, ...], Any] = {}

    def labels(self, **kv: str):
        """The child series for these label values (created on first
        use).  Label *names* are fixed at family creation."""
        if not self.enabled:
            return _NOOP_CHILD
        key = tuple(str(kv.get(ln, "")) for ln in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(
                    key, _CHILD_CLS[self.kind]())
        return child

    # label-less convenience: family proxies to the () series
    def inc(self, n: float = 1.0) -> None:
        self.labels().inc(n)

    def dec(self, n: float = 1.0) -> None:
        self.labels().dec(n)

    def set(self, v: float) -> None:
        self.labels().set(v)

    def observe(self, v: float) -> None:
        self.labels().observe(v)

    def observe_many(self, vs: Iterable[float]) -> None:
        self.labels().observe_many(vs)

    def time(self):
        return self.labels().time()

    def series(self) -> List[Tuple[Tuple[str, ...], Any]]:
        with self._lock:
            return list(self._children.items())


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def _fmt_labels(pairs: List[Tuple[str, str]]) -> str:
    if not pairs:
        return ""
    esc = [(k, v.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n")) for k, v in pairs]
    return "{" + ",".join(f'{k}="{v}"' for k, v in esc) + "}"


def _fmt_num(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


class MetricsRegistry:
    """Process-wide metric families with Prometheus text exposition.

    One registry per head service (``IDDS.metrics``); everything that
    instruments a hot path gets its family handles once (at bind time)
    and pays only a child-dict lookup + one small lock per event.
    """

    def __init__(self, head_id: str = "", prefix: str = "idds",
                 enabled: bool = True):
        self.head_id = head_id
        self.prefix = prefix
        self.enabled = enabled
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}

    # ------------------------------------------------------------ factories
    def _family(self, name: str, kind: str, help_: str,
                labels: Iterable[str]) -> _Family:
        full = f"{self.prefix}_{name}" if self.prefix else name
        with self._lock:
            fam = self._families.get(full)
            if fam is None:
                fam = _Family(full, kind, help_, tuple(labels),
                              self.enabled)
                self._families[full] = fam
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {full!r} already registered as {fam.kind}")
            return fam

    def counter(self, name: str, help: str = "",  # noqa: A002
                labels: Iterable[str] = ()) -> _Family:
        return self._family(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",  # noqa: A002
              labels: Iterable[str] = ()) -> _Family:
        return self._family(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "",  # noqa: A002
                  labels: Iterable[str] = ()) -> _Family:
        return self._family(name, "histogram", help, labels)

    # ------------------------------------------------------------ exposition
    def snapshot(self) -> Dict[str, Any]:
        """JSON-able dump of every series — what the Watchdog publishes
        into the health table for cluster-wide aggregation."""
        fams = []
        with self._lock:
            families = list(self._families.values())
        for fam in families:
            series = []
            for key, child in fam.series():
                if fam.kind == "histogram":
                    with child._lock:
                        series.append({"l": list(key),
                                       "buckets": list(child.counts),
                                       "sum": child.sum,
                                       "count": child.count})
                else:
                    series.append({"l": list(key), "v": child.value})
            fams.append({"name": fam.name, "kind": fam.kind,
                         "help": fam.help,
                         "labels": list(fam.label_names),
                         "series": series})
        return {"head": self.head_id, "families": fams}

    def render(self) -> str:
        """This head's metrics as Prometheus text exposition."""
        return render_snapshots([self.snapshot()])


def render_snapshots(snapshots: List[Dict[str, Any]]) -> str:
    """Merge one or more :meth:`MetricsRegistry.snapshot` dicts into
    one Prometheus text document.  Every series carries a ``head``
    label from its snapshot, so two heads' series never collide — this
    is the ``/v1/metrics?cluster=1`` aggregation path (snapshots come
    from the health table the Watchdog heartbeats into)."""
    # family name -> (kind, help, [(head, label_names, series), ...])
    merged: Dict[str, Tuple[str, str, List]] = {}
    order: List[str] = []
    for snap in snapshots:
        head = snap.get("head", "")
        for fam in snap.get("families", []):
            name = fam["name"]
            if name not in merged:
                merged[name] = (fam["kind"], fam.get("help", ""), [])
                order.append(name)
            merged[name][2].append((head, fam.get("labels", []),
                                    fam.get("series", [])))
    out: List[str] = []
    for name in order:
        kind, help_, groups = merged[name]
        if help_:
            out.append(f"# HELP {name} {help_}")
        out.append(f"# TYPE {name} {kind}")
        for head, label_names, series in groups:
            base = [("head", head)] if head else []
            for s in series:
                pairs = base + [(ln, lv) for ln, lv
                                in zip(label_names, s.get("l", []))]
                if kind == "histogram":
                    cum = 0
                    counts = s.get("buckets", [])
                    for i, b in enumerate(BUCKETS):
                        cum += counts[i] if i < len(counts) else 0
                        bp = pairs + [("le", _fmt_num(b))]
                        out.append(f"{name}_bucket{_fmt_labels(bp)} "
                                   f"{cum}")
                    total = s.get("count", 0)
                    bp = pairs + [("le", "+Inf")]
                    out.append(f"{name}_bucket{_fmt_labels(bp)} {total}")
                    out.append(f"{name}_sum{_fmt_labels(pairs)} "
                               f"{_fmt_num(s.get('sum', 0.0))}")
                    out.append(f"{name}_count{_fmt_labels(pairs)} "
                               f"{total}")
                else:
                    out.append(f"{name}{_fmt_labels(pairs)} "
                               f"{_fmt_num(s.get('v', 0.0))}")
    return "\n".join(out) + ("\n" if out else "")


def parse_exposition(text: str) -> Dict[str, Dict[Tuple, float]]:
    """Tiny parser for the text format (tests + the cluster-smoke
    scrape): ``{metric_name: {((label, value), ...): sample}}``."""
    out: Dict[str, Dict[Tuple, float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        body, _, value = line.rpartition(" ")
        if "{" in body:
            name, _, rest = body.partition("{")
            rest = rest.rstrip("}")
            labels = []
            for part in _split_labels(rest):
                k, _, v = part.partition("=")
                labels.append((k, v.strip('"')))
            key = tuple(labels)
        else:
            name, key = body, ()
        out.setdefault(name, {})[key] = float(value)
    return out


def _split_labels(s: str) -> List[str]:
    parts, cur, in_q = [], [], False
    for ch in s:
        if ch == '"' and (not cur or cur[-1] != "\\"):
            in_q = not in_q
        if ch == "," and not in_q:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


# ---------------------------------------------------------------------------
# Request lifecycle tracing
# ---------------------------------------------------------------------------


def new_trace_id() -> str:
    return f"tr-{uuid.uuid4().hex[:16]}"


class Tracer:
    """Journals request-lifecycle events through the store.

    Events are keyed by ``request_id`` (direct lifecycle hops) or by
    ``collection`` (content staging/availability — joined to requests
    through the works' input/output collections at read time).  Every
    event carries ``head_id`` so a cross-head trace attributes each hop
    to the head that performed it.  Emission must never break the hot
    path: store faults are counted and logged, not raised."""

    def __init__(self, store=None, head_id: str = "",
                 enabled: bool = True,
                 on_fault: Optional[Callable[[str], None]] = None):
        self.store = store
        self.head_id = head_id
        self.enabled = enabled
        self.on_fault = on_fault
        self._log = get_logger("tracer")

    def emit(self, event: str, *, request_id: Optional[str] = None,
             trace_id: Optional[str] = None,
             collection: Optional[str] = None,
             entity: Optional[str] = None,
             data: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled or self.store is None:
            return
        row = {
            "event_id": f"ev-{uuid.uuid4().hex[:16]}",
            "trace_id": trace_id,
            "request_id": request_id,
            "collection": collection,
            "event": event,
            "entity": entity,
            "head_id": self.head_id,
            # wall clock by design: peers journal into one table and
            # their monotonic clocks are not comparable
            "ts": time.time(),
            "data": data or {},
        }
        try:
            self.store.save_trace_events([row])
        except Exception as e:  # noqa: BLE001 — tracing is best-effort
            self._log.warning("trace emit failed for %s: %s", event, e)
            if self.on_fault is not None:
                self.on_fault(event)


# span name -> (start event, end event); paired per entity (entity or
# collection field, falling back to the request itself)
_SPAN_PAIRS = [
    ("marshal", "submitted", "workflow_started"),
    ("transform", "work_transforming", "work_done"),
    ("dispatch", "processing_submitted", "processing_done"),
    ("execute", "job_leased", "job_completed"),
    ("staging", "content_staging", "content_available"),
    ("delivery", "delivery_notified", "delivery_acked"),
]


def build_trace(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Reconstruct the span timeline from journaled trace events.

    Returns the ``GET /v1/requests/<id>/trace`` body: the raw events
    (sorted, with ``dt_s`` offsets from the first), named spans with
    positive durations paired per entity, and the set of heads that
    contributed (two heads after a mid-run adoption)."""
    evs = sorted(events, key=lambda e: (e.get("ts") or 0.0))
    t0 = evs[0]["ts"] if evs else 0.0
    for e in evs:
        e["dt_s"] = round((e.get("ts") or t0) - t0, 6)
    spans: List[Dict[str, Any]] = []
    for name, start_ev, end_ev in _SPAN_PAIRS:
        starts: Dict[Any, Dict] = {}
        for e in evs:
            key = e.get("entity") or e.get("collection") or ""
            if e["event"] == start_ev and key not in starts:
                starts[key] = e
            elif e["event"] == end_ev and key in starts:
                s = starts.pop(key)
                spans.append({
                    "span": name,
                    "entity": key or None,
                    "start_dt_s": s["dt_s"],
                    "end_dt_s": e["dt_s"],
                    "duration_s": round(max(e["ts"] - s["ts"], 0.0), 6),
                    "head_start": s.get("head_id"),
                    "head_end": e.get("head_id"),
                })
    spans.sort(key=lambda s: (s["start_dt_s"], s["span"]))
    heads = sorted({e.get("head_id") for e in evs if e.get("head_id")})
    trace_ids = [e.get("trace_id") for e in evs if e.get("trace_id")]
    return {
        "trace_id": trace_ids[0] if trace_ids else None,
        "events": evs,
        "spans": spans,
        "heads": heads,
        "duration_s": round(evs[-1]["ts"] - t0, 6) if evs else 0.0,
    }


# ---------------------------------------------------------------------------
# Logging
# ---------------------------------------------------------------------------

_LOG_ROOT = "repro"


class _JsonFormatter(logging.Formatter):
    """One JSON object per line: machine-ingestable structured logs
    (``--log-json``).  Known extras (head, daemon) are promoted to
    top-level keys."""

    def __init__(self, head_id: str = ""):
        super().__init__()
        self.head_id = head_id

    def format(self, record: logging.LogRecord) -> str:
        d: Dict[str, Any] = {
            "ts": round(record.created, 6),
            "level": record.levelname,
            "logger": record.name,
            "msg": record.getMessage(),
        }
        head = getattr(record, "head", None) or self.head_id
        if head:
            d["head"] = head
        for k in ("daemon", "duration_s", "event"):
            v = getattr(record, k, None)
            if v is not None:
                d[k] = v
        if record.exc_info:
            d["exc"] = self.formatException(record.exc_info)
        return json.dumps(d, sort_keys=True)


class _TextFormatter(logging.Formatter):
    def __init__(self, head_id: str = ""):
        super().__init__("%(asctime)s %(levelname)s %(name)s: "
                         "%(message)s")
        self.head_id = head_id

    def format(self, record: logging.LogRecord) -> str:
        base = super().format(record)
        head = getattr(record, "head", None) or self.head_id
        return f"[{head}] {base}" if head else base


def setup_logging(level: str = "INFO", json_mode: bool = False,
                  head_id: str = "") -> logging.Logger:
    """Configure the ``repro`` logger tree: one stderr handler with a
    head_id-tagged text or JSON formatter.  Idempotent — a second call
    replaces the handler (the rest CLI calls it once at boot)."""
    root = logging.getLogger(_LOG_ROOT)
    root.setLevel(getattr(logging, str(level).upper(), logging.INFO))
    handler = logging.StreamHandler(sys.stderr)
    handler.setFormatter(_JsonFormatter(head_id) if json_mode
                         else _TextFormatter(head_id))
    for h in list(root.handlers):
        root.removeHandler(h)
    root.addHandler(handler)
    root.propagate = False
    return root


def get_logger(name: str) -> logging.Logger:
    """A child of the ``repro`` logger tree.  Without
    :func:`setup_logging` these fall through to Python's last-resort
    handler (WARNING+ to stderr), so library use stays quiet."""
    return logging.getLogger(f"{_LOG_ROOT}.{name}")
