"""Synthetic tokenized corpus, shaped like the real thing: shards of
variable-length documents with a Zipf-ish token distribution.  Shards are
registered as ColdStore TapeFiles with *lazy* generators, so a 10k-shard
corpus costs nothing until staged — the simulator and the real pipeline
share the same corpus definition.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.carousel.storage import ColdStore, TapeFile


def synth_docs(seed: int, n_docs: int, vocab_size: int,
               mean_len: int = 512) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    lens = np.maximum(8, rng.geometric(1.0 / mean_len, n_docs))
    # Zipf-ish unigram distribution over the vocab (reserve 0=pad, 1=eod)
    ranks = np.arange(2, vocab_size)
    probs = 1.0 / ranks
    probs /= probs.sum()
    return [rng.choice(ranks, size=int(l), p=probs).astype(np.int32)
            for l in lens]


def build_cold_store(
    *,
    n_shards: int,
    docs_per_shard: int = 32,
    vocab_size: int = 256,
    mean_doc_len: int = 256,
    shard_bytes: Optional[int] = None,
    drives: int = 2,
    mount_latency: float = 0.0,
    bandwidth: float = float("inf"),
    fault_rate: float = 0.0,
    seed: int = 0,
) -> ColdStore:
    cold = ColdStore(drives=drives, mount_latency=mount_latency,
                     bandwidth=bandwidth, fault_rate=fault_rate, seed=seed)
    approx = docs_per_shard * mean_doc_len * 4
    for s in range(n_shards):
        cold.add(TapeFile(
            name=f"shard-{s:05d}",
            size=shard_bytes if shard_bytes is not None else approx,
            generator=(lambda s=s: synth_docs(
                seed * 100_003 + s, docs_per_shard, vocab_size,
                mean_doc_len)),
        ))
    return cold
