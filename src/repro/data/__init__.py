from repro.data.synthetic import (  # noqa: F401
    build_cold_store,
    synth_docs,
)
