"""Sharding-aware checkpointing (fault tolerance substrate).

Layout (one directory per step, committed atomically by rename):

    <root>/step_00000120/
        manifest.json       # tree structure + shapes/dtypes + metadata
        leaf_00000.npy ...  # one file per pytree leaf

* ``save_checkpoint``  — synchronous, atomic (tmp dir + rename), fsync'd
  manifest; safe against a node dying mid-write.
* ``AsyncCheckpointer`` — background-thread writer: the train loop only
  pays for the device->host copy, the file I/O overlaps with compute.
* ``load_checkpoint``  — rebuilds the tree; with ``shardings=`` it
  device_puts every leaf with the *target* sharding, which is how elastic
  restarts reshard a checkpoint onto a different mesh size.

Supports nested dict / list / tuple pytrees of array leaves.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}/{k}"))
        return out
    if isinstance(tree, (list, tuple)):
        out = []
        for i, v in enumerate(tree):
            out.extend(_flatten(v, f"{prefix}/[{i}]"))
        return out
    return [(prefix, tree)]


def _structure(tree: Any) -> Any:
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _structure(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": "list" if isinstance(tree, list) else "tuple",
                "items": [_structure(v) for v in tree]}
    return {"__kind__": "leaf"}


def _rebuild(struct: Any, leaves: "queue.SimpleQueue") -> Any:
    kind = struct["__kind__"]
    if kind == "dict":
        return {k: _rebuild(v, leaves)
                for k, v in sorted(struct["items"].items())}
    if kind in ("list", "tuple"):
        seq = [_rebuild(v, leaves) for v in struct["items"]]
        return seq if kind == "list" else tuple(seq)
    return leaves.get_nowait()


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:08d}")


# numpy can't round-trip ml_dtypes (bfloat16, fp8) through np.save/np.load;
# store the raw bits and the logical dtype name in the manifest instead.
def _encode(arr: np.ndarray):
    dt = arr.dtype
    if dt.kind in "fiub?c" and dt.name in np.sctypeDict:
        try:
            np.dtype(dt.name)
            if not dt.metadata and dt.name not in ("bfloat16",) and \
                    not dt.name.startswith("float8"):
                return arr, str(dt)
        except TypeError:
            pass
    return arr.view(np.uint8).reshape(arr.shape + (dt.itemsize,)), str(dt)


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    try:
        want = np.dtype(dtype_name)
        if arr.dtype == want:
            return arr
    except TypeError:
        want = None
    import ml_dtypes  # bundled with jax
    want = np.dtype(getattr(ml_dtypes, dtype_name, dtype_name))
    return arr.reshape(arr.shape[:-1] + (-1,)).view(want).reshape(
        arr.shape[:-1])


def save_checkpoint(root: str, tree: Any, step: int,
                    meta: Optional[Dict[str, Any]] = None) -> str:
    """Atomic synchronous save. Returns the committed directory."""
    os.makedirs(root, exist_ok=True)
    final = _step_dir(root, step)
    host = jax.tree.map(lambda x: np.asarray(x), tree)
    flat = _flatten(host)
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=root)
    try:
        names = []
        for i, (path, arr) in enumerate(flat):
            fname = f"leaf_{i:05d}.npy"
            enc, dtype_name = _encode(arr)
            np.save(os.path.join(tmp, fname), enc)
            names.append({"path": path, "file": fname,
                          "shape": list(arr.shape), "dtype": dtype_name})
        manifest = {"step": step, "leaves": names,
                    "structure": _structure(host), "meta": meta or {}}
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):      # overwrite = replace atomically-ish
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_") and os.path.exists(
                os.path.join(root, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(root: str, step: Optional[int] = None, *,
                    shardings: Any = None) -> Tuple[Any, Dict[str, Any]]:
    """Returns (tree, manifest_meta). ``shardings``: matching pytree of
    NamedShardings (or None) — leaves are device_put with them (elastic
    restart onto a new mesh reshards here)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = _step_dir(root, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    q: "queue.SimpleQueue" = queue.SimpleQueue()
    for leaf in manifest["leaves"]:
        raw = np.load(os.path.join(d, leaf["file"]))
        q.put(_decode(raw, leaf["dtype"]))
    tree = _rebuild(manifest["structure"], q)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            tree, shardings,
            is_leaf=lambda x: isinstance(x, np.ndarray))
    manifest["meta"]["step"] = manifest["step"]
    return tree, manifest["meta"]


class AsyncCheckpointer:
    """Single background writer; the caller pays only the host copy."""

    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._loop, daemon=True,
                                   name="ckpt-writer")
        self._t.start()

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.root)
            if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(_step_dir(self.root, s), ignore_errors=True)

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            tree, step, meta = item
            try:
                save_checkpoint(self.root, tree, step, meta)
                if self.keep:
                    self._gc()
            except BaseException as e:  # surfaced on next save()/close()
                self._err = e

    def save(self, tree: Any, step: int,
             meta: Optional[Dict[str, Any]] = None) -> None:
        if self._err is not None:
            raise RuntimeError("async checkpoint failed") from self._err
        host = jax.tree.map(lambda x: np.asarray(x), tree)  # sync copy
        self._q.put((host, step, meta))

    def close(self) -> None:
        self._q.put(None)
        self._t.join()
        if self._err is not None:
            raise RuntimeError("async checkpoint failed") from self._err
