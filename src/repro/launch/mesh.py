"""Mesh construction (functions, not module constants: importing this
module never touches jax device state).

Production target: TPU v5e pods, 256 chips each, 16x16 (data, model)
per pod; the multi-pod mesh adds a leading "pod" axis over DCN.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None) -> Mesh:
    """Mesh over whatever devices exist (CPU smoke: 1 device)."""
    n = len(jax.devices())
    model = model or 1
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (~per-chip usable)
HBM_BYTES = 16e9              # 16 GB
