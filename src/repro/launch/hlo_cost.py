"""Post-SPMD HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts every while-loop body exactly
once, so any lax.scan (layers, flash KV blocks, vocab CE blocks,
microbatch accumulation) is undercounted.  This walker parses
``compiled.as_text()`` — whose shapes are already the per-device
(partitioned) shapes — and rolls costs up from the entry computation,
multiplying while bodies by their trip count (taken from the
``known_trip_count`` backend_config, falling back to the largest integer
constant in the loop condition).

Per-device terms produced:
  flops             2*prod(out)*prod(contracting) per dot (+ conv approx)
  hbm_bytes         Σ (operands + outputs) over materializing top-level ops
                    (fusion boundaries, dots, copies, slices, collectives)
  collective_bytes  Σ operand bytes per collective kind
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4"
    r"|pred|c64|c128)\[([0-9,]*)\]")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute", "collective-broadcast",
                    "ragged-all-to-all")

# ops whose operands+outputs we count as HBM traffic.  The CPU backend
# leaves long elementwise chains unfused; a TPU build fuses them, so bare
# elementwise/convert/broadcast ops are treated as fused (skipped) and the
# traffic model is: every fusion/dot/collective/reshuffle boundary
# materializes to HBM.  Biased low for pointwise-heavy code, uniform
# across cells — documented in EXPERIMENTS.md §Roofline.
_MATERIALIZING = ("fusion", "dot", "convolution", "dynamic-slice",
                  "dynamic-update-slice", "reduce", "reduce-window", "sort",
                  "scatter", "gather", "transpose", "reshape", "slice",
                  "concatenate", "pad", "select-and-scatter", "cholesky",
                  "triangular-solve", "rng", "custom-call") \
    + COLLECTIVE_KINDS
# "copy" is excluded: on CPU it is mostly loop-carried-buffer aliasing that
# a TPU build elides via donation; counting it charges phantom traffic.
_OUT_ONLY = ()
_SKIP = ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id", "while", "call",
         "conditional", "domain", "opt-barrier", "broadcast", "iota",
         "add", "multiply", "subtract", "divide", "exponential", "tanh",
         "select", "compare", "maximum", "minimum", "convert", "and", "or",
         "not", "xor", "negate", "abs", "sign", "floor", "ceil", "sqrt",
         "rsqrt", "power", "log", "log-plus-one", "exponential-minus-one",
         "cosine", "sine", "clamp", "is-finite", "round-nearest-even",
         "shift-left", "shift-right-logical", "shift-right-arithmetic",
         "remainder", "atan2", "stochastic-convert", "reduce-precision")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    return sum(_shape_elems(dims) * _DTYPE_BYTES[dt]
               for dt, dims in _SHAPE_RE.findall(type_str))


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    operands: List[str]
    attrs: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # var -> type str


_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_NAME_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")


def _split_type_opcode(rest: str):
    """Split '<result-type> <opcode>(<...>' handling nested tuple types."""
    depth = 0
    for i, ch in enumerate(rest):
        if ch == "(":
            if depth == 0 and i > 0:
                j = i - 1
                while j >= 0 and (rest[j].isalnum() or rest[j] in "-_"):
                    j -= 1
                name = rest[j + 1:i]
                if name and not name[0].isdigit() and (j < 0 or
                                                       rest[j] in " \t"):
                    return rest[:j + 1].strip(), name, rest[i + 1:]
            depth += 1
        elif ch == ")":
            depth -= 1
    return None
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"?(\d+)')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPND_RE = re.compile(r"%([\w\.\-]+)")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        ls = raw.strip()
        if not ls or ls.startswith(("HloModule", "//", "#")):
            continue
        if ls.endswith("{") and "=" not in ls.split("(")[0]:
            m = _HDR_RE.match(ls)
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                continue
        if ls == "}" or cur is None:
            continue
        m = _NAME_RE.match(ls)
        if not m:
            continue
        name = m.group(1)
        split = _split_type_opcode(ls[m.end():])
        if split is None:
            continue
        rtype, opcode, rest = split
        # split operands (up to the matching close paren) from attributes
        depth = 1
        i = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        opnds_str, attrs = rest[:i], rest[i + 1:]
        operands = _OPND_RE.findall(opnds_str)
        inst = Instr(name, opcode, rtype.strip(), operands, attrs, ls)
        cur.instrs.append(inst)
        cur.symbols[name] = rtype.strip()
    return comps, entry


@dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    collective_total: float = 0.0

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.hbm_bytes += mult * other.hbm_bytes
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + mult * v
        self.collective_total += mult * other.collective_total


class CostWalker:
    def __init__(self, comps: Dict[str, Computation], entry: str):
        self.comps = comps
        self.entry = entry
        self._memo: Dict[str, Costs] = {}

    def _operand_bytes(self, comp: Computation, inst: Instr,
                       seen: Optional[set] = None) -> float:
        """Read traffic of an op.  With ``seen``, each buffer is charged
        once per computation execution no matter how many consumers it has
        (a value resident in HBM is streamed once; on-chip reuse after
        that) — without it the multi-consumer fan-out inflates ~3x."""
        tot = 0.0
        for o in inst.operands:
            if seen is not None:
                if o in seen:
                    continue
                seen.add(o)
            t = comp.symbols.get(o)
            if t:
                tot += _type_bytes(t)
        return tot

    def _dot_flops(self, comp: Computation, inst: Instr) -> float:
        out = _SHAPE_RE.findall(inst.result_type)
        out_elems = _shape_elems(out[0][1]) if out else 0
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.attrs) or \
            re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.line)
        contract = 1
        if m and inst.operands:
            lhs_t = comp.symbols.get(inst.operands[0], "")
            sh = _SHAPE_RE.findall(lhs_t)
            if sh:
                dims = [int(d) for d in sh[0][1].split(",") if d.strip()]
                for idx in m.group(1).split(","):
                    if idx.strip() and int(idx) < len(dims):
                        contract *= dims[int(idx)]
        return 2.0 * out_elems * contract

    def _conv_flops(self, comp: Computation, inst: Instr) -> float:
        out = _SHAPE_RE.findall(inst.result_type)
        if not out or len(inst.operands) < 2:
            return 0.0
        out_elems = _shape_elems(out[0][1])
        k_t = comp.symbols.get(inst.operands[1], "")
        sh = _SHAPE_RE.findall(k_t)
        if not sh:
            return 0.0
        kdims = [int(d) for d in sh[0][1].split(",") if d.strip()]
        co = kdims[-1] if kdims else 1
        import math
        return 2.0 * out_elems * (math.prod(kdims) / max(co, 1))

    def _trip_count(self, inst: Instr) -> int:
        m = _TRIP_RE.search(inst.line)
        if m:
            return int(m.group(1))
        cm = _COND_RE.search(inst.line)
        if cm and cm.group(1) in self.comps:
            consts = []
            for ci in self.comps[cm.group(1)].instrs:
                consts += [int(c) for c in _CONST_RE.findall(ci.line)]
            if consts:
                return max(consts)
        return 1

    def comp_costs(self, name: str) -> Costs:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Costs()  # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return self._memo[name]
        c = Costs()
        seen_reads: set = set()
        for inst in comp.instrs:
            op = inst.opcode
            base = op
            for suff in ("-start", "-done"):
                if base.endswith(suff):
                    base = base[: -len(suff)]
            if op.endswith("-done"):
                continue
            if base == "while":
                trips = self._trip_count(inst)
                bm = _BODY_RE.search(inst.line)
                if bm:
                    c.add(self.comp_costs(bm.group(1)), trips)
                continue
            if base in ("call", "conditional"):
                for callee in _CALLS_RE.findall(inst.line):
                    c.add(self.comp_costs(callee), 1.0)
                continue
            if base == "fusion":
                # count the fusion's DOTS (they run on the MXU) but not its
                # internal elementwise ops; bytes at the fusion boundary
                for callee in _CALLS_RE.findall(inst.attrs):
                    c.flops += self._fusion_dot_flops(callee)
                c.hbm_bytes += self._fusion_bytes(comp, inst, seen_reads)
                continue
            if base == "dynamic-update-slice":
                # in-place: traffic = the update slice (read + write)
                upd = (comp.symbols.get(inst.operands[1], "")
                       if len(inst.operands) > 1 else inst.result_type)
                c.hbm_bytes += 2 * _type_bytes(upd)
                continue
            if base in ("dynamic-slice", "gather"):
                c.hbm_bytes += 2 * _type_bytes(inst.result_type)
                continue
            if base == "scatter":
                upd = (comp.symbols.get(inst.operands[2], "")
                       if len(inst.operands) > 2 else inst.result_type)
                c.hbm_bytes += 2 * _type_bytes(upd)
                continue
            if base in COLLECTIVE_KINDS:
                b = self._operand_bytes(comp, inst)
                c.collectives[base] = c.collectives.get(base, 0.0) + b
                c.collective_total += b
                c.hbm_bytes += b + _type_bytes(inst.result_type)
                continue
            if base == "dot":
                c.flops += self._dot_flops(comp, inst)
                c.hbm_bytes += (_type_bytes(inst.result_type)
                                + self._operand_bytes(comp, inst, seen_reads))
                continue
            if base == "convolution":
                c.flops += self._conv_flops(comp, inst)
                c.hbm_bytes += (_type_bytes(inst.result_type)
                                + self._operand_bytes(comp, inst, seen_reads))
                continue
            if base in _OUT_ONLY:
                c.hbm_bytes += _type_bytes(inst.result_type)
                continue
            if base in _SKIP:
                continue
            if base in _MATERIALIZING or base.startswith("wrapped"):
                c.hbm_bytes += (_type_bytes(inst.result_type)
                                + self._operand_bytes(comp, inst, seen_reads))
        self._memo[name] = c
        return c

    def _fusion_bytes(self, comp: Computation, inst: Instr,
                      seen: Optional[set] = None) -> float:
        """Fusion boundary traffic.  In-place update fusions (root =
        dynamic-update-slice / scatter) move only the updated slice, not
        the aliased buffer; slice-read fusions move only the slice."""
        callees = _CALLS_RE.findall(inst.attrs)
        root = None
        callee_comp = self.comps.get(callees[0]) if callees else None
        if callee_comp is not None:
            for ci in callee_comp.instrs:
                if ci.line.startswith("ROOT"):
                    root = ci
            if root is None and callee_comp.instrs:
                root = callee_comp.instrs[-1]
        if root is not None and root.opcode in ("dynamic-update-slice",
                                                "scatter"):
            idx = 1 if root.opcode == "dynamic-update-slice" else 2
            upd_t = (callee_comp.symbols.get(root.operands[idx], "")
                     if len(root.operands) > idx else "")
            small = sum(_type_bytes(comp.symbols.get(o, ""))
                        for o in inst.operands
                        if _type_bytes(comp.symbols.get(o, ""))
                        < 0.5 * _type_bytes(inst.result_type))
            return 2 * _type_bytes(upd_t) + small
        if root is not None and root.opcode in ("dynamic-slice",):
            return 2 * _type_bytes(inst.result_type)
        return (_type_bytes(inst.result_type)
                + self._operand_bytes(comp, inst, seen))

    def _fusion_dot_flops(self, callee: str) -> float:
        comp = self.comps.get(callee)
        if comp is None:
            return 0.0
        f = 0.0
        for inst in comp.instrs:
            if inst.opcode == "dot":
                f += self._dot_flops(comp, inst)
            elif inst.opcode == "convolution":
                f += self._conv_flops(comp, inst)
            elif inst.opcode == "fusion":
                for c2 in _CALLS_RE.findall(inst.attrs):
                    f += self._fusion_dot_flops(c2)
        return f


def breakdown(text: str, top: int = 20) -> List[Tuple[str, str, float]]:
    """(opcode, result_type, bytes) top contributors — §Perf attribution."""
    comps, entry = parse_module(text)
    if entry is None:
        entry = next(iter(comps)) if comps else ""
    w = CostWalker(comps, entry)
    items: Dict[Tuple[str, str], float] = {}

    def walk(name: str, mult: float, seen: Tuple[str, ...] = ()):
        comp = comps.get(name)
        if comp is None or name in seen:
            return
        for inst in comp.instrs:
            base = inst.opcode
            for suff in ("-start", "-done"):
                if base.endswith(suff):
                    base = base[:-len(suff)]
            if inst.opcode.endswith("-done"):
                continue
            if base == "while":
                m = _BODY_RE.search(inst.line)
                if m:
                    walk(m.group(1), mult * w._trip_count(inst),
                         seen + (name,))
                continue
            if base in ("call", "conditional"):
                for c2 in _CALLS_RE.findall(inst.line):
                    walk(c2, mult, seen + (name,))
                continue
            if base in _SKIP or base == "copy" or base in _OUT_ONLY:
                continue
            if base == "fusion":
                b = w._fusion_bytes(comp, inst)
            elif base == "dynamic-update-slice":
                upd = (comp.symbols.get(inst.operands[1], "")
                       if len(inst.operands) > 1 else inst.result_type)
                b = 2 * _type_bytes(upd)
            elif base in ("dynamic-slice", "gather"):
                b = 2 * _type_bytes(inst.result_type)
            else:
                b = (_type_bytes(inst.result_type)
                     + w._operand_bytes(comp, inst))
            key = (base, inst.result_type[:60])
            items[key] = items.get(key, 0.0) + mult * b

    walk(entry, 1.0)
    out = sorted(((op, t, b) for (op, t), b in items.items()),
                 key=lambda x: -x[2])
    return out[:top]


def analyze(text: str) -> Dict[str, float]:
    comps, entry = parse_module(text)
    if entry is None:
        entry = next(iter(comps)) if comps else ""
    w = CostWalker(comps, entry)
    c = w.comp_costs(entry)
    out = {"flops": c.flops, "hbm_bytes": c.hbm_bytes,
           "collective_bytes": c.collective_total}
    for k, v in c.collectives.items():
        out[f"coll_{k}"] = v
    return out


def xla_cost(compiled) -> Dict[str, float]:
    """XLA's own ``compiled.cost_analysis()``, normalized across jax
    versions (older releases return a list with one dict per program)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
