"""Batched serving driver: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
        --prompt-len 32 --gen 16 --batch 4
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (RunConfig, ShapeConfig, get_config,
                                get_smoke_config)
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.serve import engine
from repro.sharding import ShardingRules, use_rules


def run_serving(arch: str, *, smoke: bool = True, prompt_len: int = 32,
                gen: int = 16, batch: int = 4,
                run: Optional[RunConfig] = None) -> Dict[str, Any]:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    run = run or RunConfig()
    mesh = make_host_mesh()
    rules = ShardingRules(mesh)

    shape = ShapeConfig("serve", prompt_len, batch, "prefill")
    prompts = registry.synth_inputs(jax.random.PRNGKey(0), cfg, shape,
                                    "prefill")
    extra = cfg.num_img_patches if cfg.family == "vlm" else 0
    max_len = prompt_len + extra + gen + 8

    prefill = jax.jit(engine.make_prefill_step(cfg, run),
                      donate_argnums=(2,))
    decode = jax.jit(engine.make_decode_step(cfg, run), donate_argnums=(2,))

    with use_rules(rules):
        params = __import__("repro.train.step", fromlist=["init_state"]) \
            .init_state(jax.random.PRNGKey(1), cfg, run)["params"]
        cache = engine.init_cache(cfg, batch, max_len)
        t0 = time.time()
        tok, cache = prefill(params, prompts, cache)
        tok.block_until_ready()
        t_prefill = time.time() - t0
        out_tokens = [tok]
        pos = prompt_len + extra
        t1 = time.time()
        for i in range(gen - 1):
            tok, cache = decode(params, tok, cache,
                                jnp.asarray(pos + i, jnp.int32))
            out_tokens.append(tok)
        jax.block_until_ready(out_tokens[-1])
        t_decode = time.time() - t1
    seq = jnp.concatenate(out_tokens, axis=1)
    return {
        "arch": arch,
        "generated": seq.shape,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
        "tokens": seq,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args(argv)
    res = run_serving(args.arch, smoke=args.smoke,
                      prompt_len=args.prompt_len, gen=args.gen,
                      batch=args.batch)
    res.pop("tokens")
    print(res)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
