"""Production trainer: carousel-fed, checkpointed, resumable, elastic.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 --out /tmp/run1 [--resume] [--no-carousel]

The input pipeline is the paper's machinery end to end: a ColdStore corpus
staged by the Stager (with retries + hedged stragglers), transformed
on-demand into packed sequences, and delivered incrementally by the
DeliveryIterator — training starts when the FIRST shard lands.
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp

from repro.carousel.delivery import DeliveryIterator
from repro.carousel.stager import Stager
from repro.carousel.storage import DiskCache
from repro.carousel.transform import make_packing_transform
from repro.ckpt import AsyncCheckpointer, latest_step, load_checkpoint
from repro.configs.base import (RunConfig, ShapeConfig, get_config,
                                get_smoke_config)
from repro.data.synthetic import build_cold_store
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.sharding import ShardingRules, param_shardings, use_rules
from repro.train.step import init_state, make_train_step


def make_carousel_pipeline(cfg, *, seq_len: int, batch_rows: int,
                           n_shards: int = 64, fault_rate: float = 0.02,
                           cache_bytes: int = 1 << 30, coarse: bool = False,
                           tape_latency: float = 0.001, drives: int = 4):
    cold = build_cold_store(
        n_shards=n_shards, docs_per_shard=16, vocab_size=cfg.vocab_size,
        mean_doc_len=seq_len // 2, drives=drives,
        mount_latency=tape_latency, fault_rate=fault_rate)
    cache = DiskCache(cache_bytes)
    names = [f.name for f in cold.files()]
    stager = Stager(cold, cache, workers=4, max_attempts=6, backoff=0.005,
                    transform=make_packing_transform(seq_len))
    stager.submit_all(names)
    delivery = DeliveryIterator(stager, cache, names,
                                batch_rows=batch_rows, coarse=coarse)
    return stager, delivery


def _batch_iter_carousel(cfg, shape, delivery) -> Iterator[Dict[str, Any]]:
    extra = _modality_extras(cfg, shape)
    for b in delivery:
        out = {k: jnp.asarray(v) for k, v in b.items()}
        out.update(extra)
        yield out


def _modality_extras(cfg, shape) -> Dict[str, Any]:
    B = shape.global_batch
    if cfg.family == "encdec":
        return {"frames": jnp.zeros((B, cfg.encoder_frames, cfg.d_model),
                                    jnp.bfloat16)}
    if cfg.family == "vlm":
        return {"img_embeds": jnp.zeros((B, cfg.num_img_patches,
                                         cfg.d_model), jnp.bfloat16)}
    return {}


def _batch_iter_synth(cfg, shape) -> Iterator[Dict[str, Any]]:
    i = 0
    while True:
        yield registry.synth_inputs(jax.random.PRNGKey(i), cfg, shape,
                                    "train")
        i += 1


def run_training(
    arch: str,
    *,
    smoke: bool = True,
    steps: int = 20,
    seq_len: int = 64,
    global_batch: int = 4,
    out_dir: Optional[str] = None,
    resume: bool = False,
    carousel: bool = True,
    coarse: bool = False,
    ckpt_every: int = 10,
    tape_latency: float = 0.001,
    drives: int = 4,
    run: Optional[RunConfig] = None,
    on_step: Optional[Callable[[int, Dict[str, float]], None]] = None,
) -> Dict[str, Any]:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    shape = ShapeConfig("train", seq_len, global_batch, "train")
    run = run or RunConfig(total_steps=max(steps, 10), warmup_steps=2,
                           ce_block_v=max(64, cfg.vocab_size // 8))

    mesh = make_host_mesh()
    rules = ShardingRules(mesh)
    step_fn = jax.jit(make_train_step(cfg, run), donate_argnums=(0,))

    start_step = 0
    if resume and out_dir and latest_step(out_dir) is not None:
        defs = registry.param_defs(cfg)
        p_sh = param_shardings(defs, rules)
        shardings = {"params": p_sh,
                     "opt": {"m": jax.tree.map(lambda s: s, p_sh),
                             "v": jax.tree.map(lambda s: s, p_sh),
                             "step": None}}
        state, meta = load_checkpoint(out_dir, shardings=None)
        state = jax.tree.map(jnp.asarray, state)
        start_step = int(meta["step"])
    else:
        state = init_state(jax.random.PRNGKey(0), cfg, run)

    ckpt = AsyncCheckpointer(out_dir, keep=3) if out_dir else None
    stager = None
    if carousel:
        stager, delivery = make_carousel_pipeline(
            cfg, seq_len=seq_len, batch_rows=global_batch,
            n_shards=max(8, steps), coarse=coarse,
            tape_latency=tape_latency, drives=drives)
        batches = _batch_iter_carousel(cfg, shape, delivery)
    else:
        batches = _batch_iter_synth(cfg, shape)

    losses: List[float] = []
    t0 = time.time()
    ttfb = None
    with use_rules(rules):
        done = start_step
        for batch in batches:
            if done >= start_step + steps:
                break
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            if ttfb is None:
                ttfb = time.time() - t0
            losses.append(loss)
            done += 1
            if on_step:
                on_step(done, {"loss": loss})
            if ckpt and done % ckpt_every == 0:
                ckpt.save(state, done, meta={"loss": loss, "arch": arch})
    if ckpt:
        ckpt.save(state, done, meta={"loss": losses[-1] if losses else None,
                                     "arch": arch})
        ckpt.close()
    if stager:
        stager.shutdown()
    return {
        "arch": arch,
        "steps": len(losses),
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "losses": losses,
        "time_to_first_batch_s": ttfb,
        "wall_s": time.time() - t0,
        "final_step": done,
        "state": state,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--out")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--no-carousel", dest="carousel", action="store_false")
    ap.add_argument("--coarse", action="store_true",
                    help="pre-iDDS baseline: wait for the whole dataset")
    args = ap.parse_args(argv)
    res = run_training(args.arch, smoke=args.smoke, steps=args.steps,
                       seq_len=args.seq_len, global_batch=args.global_batch,
                       out_dir=args.out, resume=args.resume,
                       carousel=args.carousel, coarse=args.coarse)
    res.pop("state")
    res.pop("losses")
    print(res)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
