"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
compiles, and fits — and extract the roofline terms from the compiled
artifact.  MUST be executed as its own process (the XLA_FLAGS lines below
run before any jax import).

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results.json
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs.base import (RunConfig, SHAPES, all_cells, cell_is_runnable,
                                get_config)
from repro.launch import hlo_cost
from repro.launch import mesh as mesh_lib
from repro.models import params as P
from repro.models import registry
from repro.serve import engine
from repro.sharding import ShardingRules, param_shardings, use_rules
from repro.train import step as train_step_lib


def batch_shardings(rules: ShardingRules, specs: Dict[str, Any]):
    """Inputs: shard the leading batch dim on (pod, data); rest replicated."""
    def one(s):
        if not hasattr(s, "shape") or len(s.shape) == 0:
            return rules.sharding((), ())
        logical = ["batch"] + [None] * (len(s.shape) - 1)
        return rules.sharding(logical, s.shape)
    return jax.tree.map(one, specs)


def state_shardings(cfg, run, rules: ShardingRules):
    defs = registry.param_defs(cfg)
    p_sh = param_shardings(defs, rules)
    return {
        "params": p_sh,
        "opt": {"m": jax.tree.map(lambda s: s, p_sh),
                "v": jax.tree.map(lambda s: s, p_sh),
                "step": rules.sharding((), ())},
    }


def cache_shardings(cfg, rules: ShardingRules, batch: int, max_len: int):
    defs = engine.cache_defs(cfg, batch, max_len)
    return P.tree_map(lambda d: rules.sharding(d.logical, d.shape), defs)


def default_run_config(arch: str, shape_name: str,
                       overrides: Optional[Dict[str, Any]] = None,
                       ) -> RunConfig:
    run = RunConfig()
    if (arch, shape_name) == ("zamba2-1.2b", "long_500k"):
        # XLA CPU segfaults compiling the scanned variant of this one
        # program (hybrid decode w/ 500k KV); the unrolled build compiles
        # and yields identical roofline terms. 38 layers unroll cheaply.
        run = run.replace(scan_layers=False)
    if overrides:
        run = run.replace(**overrides)
    return run


def lower_cell(arch: str, shape_name: str, mesh, *,
               run_overrides: Optional[Dict[str, Any]] = None):
    """Build + lower one cell. Returns (lowered, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    run = default_run_config(arch, shape_name, run_overrides)
    rules = ShardingRules(mesh)
    specs = registry.input_specs(cfg, shape)

    with use_rules(rules):
        if shape.kind == "train":
            state_abs = train_step_lib.abstract_state(cfg, run)
            st_sh = state_shardings(cfg, run, rules)
            b_sh = batch_shardings(rules, specs)
            fn = train_step_lib.make_train_step(cfg, run)
            lowered = jax.jit(
                fn, in_shardings=(st_sh, b_sh),
                out_shardings=(st_sh, None),
                donate_argnums=(0,),
            ).lower(state_abs, specs)
        elif shape.kind == "prefill":
            params_abs = P.abstract(registry.param_defs(cfg))
            defs = registry.param_defs(cfg)
            p_sh = param_shardings(defs, rules)
            # vlm prefill writes img_patches + text tokens into the cache
            max_len = shape.seq_len + cfg.num_img_patches + 8
            cache_abs = engine.abstract_cache(cfg, shape.global_batch,
                                              max_len)
            c_sh = cache_shardings(cfg, rules, shape.global_batch, max_len)
            b_sh = batch_shardings(rules, specs)
            fn = engine.make_prefill_step(cfg, run)
            lowered = jax.jit(
                fn, in_shardings=(p_sh, b_sh, c_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(2,),
            ).lower(params_abs, specs, cache_abs)
        else:  # decode
            params_abs = P.abstract(registry.param_defs(cfg))
            defs = registry.param_defs(cfg)
            p_sh = param_shardings(defs, rules)
            cache_abs = engine.abstract_cache(cfg, shape.global_batch,
                                              shape.seq_len)
            c_sh = cache_shardings(cfg, rules, shape.global_batch,
                                   shape.seq_len)
            tok_sh = rules.sharding(("batch", None), (shape.global_batch, 1))
            fn = engine.make_decode_step(cfg, run)
            lowered = jax.jit(
                fn, in_shardings=(p_sh, tok_sh, c_sh, None),
                out_shardings=(tok_sh, c_sh),
                donate_argnums=(2,),
            ).lower(params_abs, specs["tokens"], cache_abs, specs["pos"])

    n_params = P.param_count(registry.param_defs(cfg))
    return lowered, {"arch": arch, "shape": shape_name, "kind": shape.kind,
                     "n_params": n_params}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             mesh=None, run_overrides: Optional[Dict[str, Any]] = None,
             collect_hlo: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    t0 = time.time()
    if mesh is None:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    try:
        lowered, meta = lower_cell(arch, shape_name, mesh,
                                   run_overrides=run_overrides)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        mem = compiled.memory_analysis()
        cost = hlo_cost.xla_cost(compiled)
        # our walker: per-device flops/bytes with while-loop trip counts
        # (XLA's cost_analysis counts loop bodies once — see hlo_cost.py)
        walk = hlo_cost.analyze(compiled.as_text()) if collect_hlo else {}
        out = {
            **meta,
            "status": "ok",
            "mesh": (f"{'pod2x' if multi_pod else ''}"
                     f"{tuple(mesh.shape.values())}"),
            "chips": n_chips,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "hlo_flops": walk.get("flops", 0.0),           # per device
            "hlo_bytes": walk.get("hbm_bytes", 0.0),       # per device
            "collective_bytes": {
                k.replace("coll_", ""): v for k, v in walk.items()
                if k.startswith("coll_")},
            "collective_total": walk.get("collective_bytes", 0.0),
            "xla_cost_flops": float(cost.get("flops", 0.0)),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            },
        }
        out["model_flops"] = model_flops(cfg, shape)
        out["roofline"] = roofline_terms(out)
        return out
    except Exception as e:
        return {"arch": arch, "shape": shape_name, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}


def active_params(cfg) -> int:
    """Params touched per token: excludes the input embedding gather; MoE
    counts only the top-k routed experts."""
    defs = registry.param_defs(cfg)
    total = P.param_count(defs)
    emb = int(cfg.vocab_size) * int(cfg.d_model)
    total -= emb  # tok embedding (gather, not matmul)
    if cfg.num_experts and cfg.num_experts_per_tok:
        per_layer_expert = 3 * cfg.d_model * cfg.d_ff  # gate+up+down
        inactive = (cfg.num_experts - cfg.num_experts_per_tok)
        total -= cfg.num_layers * inactive * per_layer_expert
    return int(total)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (fwd-only), N = active params,
    D = tokens processed. Global (all chips)."""
    N = active_params(cfg)
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        return 6.0 * N * D
    if shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        return 2.0 * N * D
    return 2.0 * N * shape.global_batch  # decode: one token per sequence


def roofline_terms(cell: Dict[str, Any]) -> Dict[str, Any]:
    chips = cell["chips"]
    flops = cell["hlo_flops"]       # per device (hlo_cost walker)
    byts = cell["hlo_bytes"]        # per device
    coll = cell.get("collective_total", 0.0)  # per device
    t_c = flops / mesh_lib.PEAK_FLOPS_BF16
    t_m = byts / mesh_lib.HBM_BW
    t_n = coll / mesh_lib.ICI_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n}
    dom = max(terms, key=terms.get)
    bound = max(t_c, t_m, t_n, 1e-30)
    terms["dominant"] = dom
    terms["bound_s"] = bound
    terms["compute_fraction"] = t_c / bound
    mf = cell.get("model_flops", 0.0)
    terms["useful_flops_ratio"] = mf / (flops * chips) if flops else 0.0
    return terms


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--run-overrides", help="JSON dict of RunConfig fields")
    args = ap.parse_args(argv)

    overrides = json.loads(args.run_overrides) if args.run_overrides else None
    cells = []
    if args.all:
        cells = [(a, s) for a, s, ok, _ in all_cells()]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape or --all required")
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    n_bad = 0
    for mp in meshes:
        mesh = mesh_lib.make_production_mesh(multi_pod=mp)
        for arch, shape in cells:
            r = run_cell(arch, shape, multi_pod=mp, mesh=mesh,
                         run_overrides=overrides)
            results.append(r)
            status = r["status"]
            line = (f"[{status}] {arch} x {shape} "
                    f"mesh={'2x16x16' if mp else '16x16'}")
            if status == "ok":
                rf = r["roofline"]
                line += (f" flops/dev={r['hlo_flops']:.3e}"
                         f" bytes/dev={r['hlo_bytes']:.3e}"
                         f" coll/dev={r['collective_total']:.3e}"
                         f" dom={rf['dominant'][:-2]}"
                         f" bound={rf['bound_s']*1e3:.1f}ms"
                         f" useful={rf['useful_flops_ratio']:.2f}"
                         f" compile={r['compile_s']}s")
            elif status == "error":
                n_bad += 1
                line += " " + r["error"]
            else:
                line += f" ({r['reason'][:60]})"
            print(line, flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    return 1 if n_bad else 0


if __name__ == "__main__":
    sys.exit(main())
