"""The worker agent: lease -> execute -> report (one pilot).

An agent is a client of the REST gateway and nothing more — it holds no
head-service state, so any number of agents on any number of hosts can
pull from one head.  While a payload runs, a background thread renews
the lease at ``ttl / 3``; if the head declares the lease lost (409),
the agent drops the job — the head has already requeued it, and a stale
completion would be rejected with the same 409.

Payloads resolve against the *local* registry
(:mod:`repro.core.payloads`), exactly as PanDA pilots resolve
transformation names on the worker node: the head ships names and
params, never code.
"""
from __future__ import annotations

import os
import socket
import threading
import traceback
import uuid
from typing import Any, Dict, List, Optional, Tuple

from repro.core import payloads as reg
from repro.core.client import ConflictError, IDDSClient, IDDSClientError
from repro.core.idds import AuthError


def default_worker_id(suffix: str = "") -> str:
    base = f"{socket.gethostname()}-{os.getpid()}"
    return f"{base}{suffix}" if suffix else \
        f"{base}-{uuid.uuid4().hex[:6]}"


class WorkerAgent:
    def __init__(self, url: str, *, token: str = "",
                 worker_id: Optional[str] = None,
                 queues: Optional[List[str]] = None,
                 lease_ttl: float = 30.0, poll_interval: float = 0.25,
                 client: Optional[IDDSClient] = None,
                 verbose: bool = False):
        self.worker_id = worker_id or default_worker_id()
        self.client = client if client is not None else \
            IDDSClient(url, token=token)
        self.queues = list(queues) if queues else None
        self.lease_ttl = lease_ttl
        self.poll_interval = poll_interval
        self.verbose = verbose
        # counters (read by the pool/CLI for the exit summary)
        self.jobs_done = 0
        self.jobs_failed = 0
        self.leases_lost = 0
        self.transport_errors = 0

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(f"[{self.worker_id}] {msg}", flush=True)

    # ---------------------------------------------------------- execution
    def _execute(self, job: Dict[str, Any]) -> Tuple[Optional[Dict],
                                                     Optional[str]]:
        try:
            fn = reg.get_payload(job["payload"])
            return fn(dict(job["params"]), list(job["input_files"])), None
        except Exception as e:  # noqa: BLE001 — becomes a reported failure
            return None, f"{type(e).__name__}: {e}"

    def process(self, job: Dict[str, Any]) -> bool:
        """Execute one leased job under heartbeat renewal and report the
        outcome; returns True unless the lease was lost mid-run."""
        job_id = job["job_id"]
        ttl = float(job.get("lease", {}).get("ttl", self.lease_ttl))
        stop_hb = threading.Event()
        lost = threading.Event()

        def _renew() -> None:
            while not stop_hb.wait(max(ttl / 3.0, 0.02)):
                try:
                    self.client.heartbeat_job(job_id, self.worker_id)
                except ConflictError:
                    lost.set()  # head requeued the job; stop renewing
                    return
                except (IDDSClientError, AuthError, OSError):
                    # transient transport trouble: the lease may still be
                    # live on the head — keep trying until it expires
                    self.transport_errors += 1

        hb = threading.Thread(target=_renew, daemon=True,
                              name=f"hb-{self.worker_id}")
        hb.start()
        try:
            result, error = self._execute(job)
        finally:
            stop_hb.set()
        hb.join(timeout=2.0)
        if lost.is_set():
            self.leases_lost += 1
            self._log(f"lease lost mid-run for {job_id} (requeued by head)")
            return False
        try:
            self.client.complete_job(job_id, self.worker_id,
                                     result=result, error=error)
        except ConflictError:
            # expired between last heartbeat and completion: the head
            # already handed the job to someone else — drop it
            self.leases_lost += 1
            self._log(f"completion rejected for {job_id} (stale lease)")
            return False
        if error:
            self.jobs_failed += 1
            self._log(f"job {job_id} failed: {error}")
        else:
            self.jobs_done += 1
            self._log(f"job {job_id} done (attempt {job['attempt']})")
        return True

    # --------------------------------------------------------------- loop
    def run_once(self) -> bool:
        """One lease attempt; returns True if a job was processed."""
        job = self.client.lease_job(self.worker_id, queues=self.queues,
                                    ttl=self.lease_ttl)
        if job is None:
            return False
        self.process(job)
        return True

    def run(self, stop: threading.Event) -> None:
        """Pull until ``stop`` is set.  Transport errors back off and
        retry — a worker outliving a head restart reconnects by itself.
        Auth failures are permanent (a bad or revoked token cannot heal
        by retrying), so they stop the agent loudly instead."""
        idle_wait = self.poll_interval
        while not stop.is_set():
            try:
                worked = self.run_once()
                idle_wait = self.poll_interval
            except AuthError as e:
                print(f"[{self.worker_id}] auth rejected by head, "
                      f"stopping: {e}", flush=True)
                return
            except (IDDSClientError, OSError) as e:
                self.transport_errors += 1
                self._log(f"transport error: {e}")
                worked = False
                # capped backoff so a dead head isn't hammered
                idle_wait = min(max(idle_wait * 2, self.poll_interval), 5.0)
            except Exception:  # pragma: no cover — agent resilience
                traceback.print_exc()
                worked = False
            if not worked:
                stop.wait(idle_wait)

    def stats(self) -> Dict[str, int]:
        return {"jobs_done": self.jobs_done,
                "jobs_failed": self.jobs_failed,
                "leases_lost": self.leases_lost,
                "transport_errors": self.transport_errors}
