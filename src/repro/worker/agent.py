"""The worker agent: lease -> execute -> report (one pilot).

An agent is a client of the REST gateway and nothing more — it holds no
head-service state, so any number of agents on any number of hosts can
pull from one head.  While a payload runs, a background thread renews
the lease at ``ttl / 3``; if the head declares the lease lost (409),
the agent drops the job — the head has already requeued it, and a stale
completion would be rejected with the same 409.

Payloads resolve against the *local* registry
(:mod:`repro.core.payloads`), exactly as PanDA pilots resolve
transformation names on the worker node: the head ships names and
params, never code.

Each agent tracks the input contents it has recently processed in a
small LRU (:class:`ContentCache`) and reports that manifest with every
lease request and heartbeat.  An intel-enabled head uses the manifest
for cache-affinity routing — jobs whose inputs the worker already
holds are preferred — while a legacy head simply ignores the field.
"""
from __future__ import annotations

import os
import queue
import socket
import threading
import traceback
import uuid
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

from repro.core import payloads as reg
from repro.core.client import ConflictError, IDDSClient, IDDSClientError
from repro.core.idds import AuthError


def default_worker_id(suffix: str = "") -> str:
    base = f"{socket.gethostname()}-{os.getpid()}"
    return f"{base}{suffix}" if suffix else \
        f"{base}-{uuid.uuid4().hex[:6]}"


class ContentCache:
    """LRU of content names this worker has recently pulled locally.

    Models the pilot-side data cache: processing a job leaves its input
    files on local disk, so a subsequent job over the same files skips
    the transfer.  The scheduler only ever sees the *names* (the
    manifest) — actual bytes live wherever the payload put them.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._entries: "OrderedDict[str, None]" = OrderedDict()

    def touch(self, names: List[str]) -> None:
        """Mark ``names`` as freshly held, evicting the LRU overflow."""
        with self._lock:
            for n in names:
                self._entries.pop(n, None)
                self._entries[n] = None
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    def manifest(self) -> List[str]:
        with self._lock:
            return list(self._entries)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


class WorkerAgent:
    def __init__(self, url: str, *, token: str = "",
                 worker_id: Optional[str] = None,
                 queues: Optional[List[str]] = None,
                 lease_ttl: float = 30.0, poll_interval: float = 0.25,
                 client: Optional[IDDSClient] = None,
                 cache_capacity: int = 256,
                 verbose: bool = False):
        self.worker_id = worker_id or default_worker_id()
        self.client = client if client is not None else \
            IDDSClient(url, token=token)
        self.queues = list(queues) if queues else None
        self.lease_ttl = lease_ttl
        self.poll_interval = poll_interval
        self.verbose = verbose
        self.cache = ContentCache(cache_capacity)
        # counters (read by the pool/CLI for the exit summary)
        self.jobs_done = 0
        self.jobs_failed = 0
        self.leases_lost = 0
        self.transport_errors = 0

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(f"[{self.worker_id}] {msg}", flush=True)

    # ---------------------------------------------------------- execution
    def _execute(self, job: Dict[str, Any]) -> Tuple[Optional[Dict],
                                                     Optional[str]]:
        try:
            fn = reg.get_payload(job["payload"])
            return fn(dict(job["params"]), list(job["input_files"])), None
        except Exception as e:  # noqa: BLE001 — becomes a reported failure
            return None, f"{type(e).__name__}: {e}"

    def process(self, job: Dict[str, Any]) -> bool:
        """Execute one leased job under heartbeat renewal and report the
        outcome; returns True unless the lease was lost mid-run."""
        job_id = job["job_id"]
        ttl = float(job.get("lease", {}).get("ttl", self.lease_ttl))
        stop_hb = threading.Event()
        lost = threading.Event()

        def _renew() -> None:
            while not stop_hb.wait(max(ttl / 3.0, 0.02)):
                try:
                    self.client.heartbeat_job(
                        job_id, self.worker_id,
                        manifest=self.cache.manifest())
                except ConflictError:
                    lost.set()  # head requeued the job; stop renewing
                    return
                except (IDDSClientError, AuthError, OSError):
                    # transient transport trouble: the lease may still be
                    # live on the head — keep trying until it expires
                    self.transport_errors += 1

        hb = threading.Thread(target=_renew, daemon=True,
                              name=f"hb-{self.worker_id}")
        hb.start()
        # executing the payload pulls its inputs onto local disk — they
        # are part of this worker's manifest from here on
        self.cache.touch(list(job.get("input_files") or []))
        try:
            result, error = self._execute(job)
        finally:
            stop_hb.set()
        hb.join(timeout=2.0)
        if lost.is_set():
            self.leases_lost += 1
            self._log(f"lease lost mid-run for {job_id} (requeued by head)")
            return False
        try:
            self.client.complete_job(job_id, self.worker_id,
                                     result=result, error=error)
        except ConflictError:
            # expired between last heartbeat and completion: the head
            # already handed the job to someone else — drop it
            self.leases_lost += 1
            self._log(f"completion rejected for {job_id} (stale lease)")
            return False
        if error:
            self.jobs_failed += 1
            self._log(f"job {job_id} failed: {error}")
        else:
            self.jobs_done += 1
            self._log(f"job {job_id} done (attempt {job['attempt']})")
        return True

    # --------------------------------------------------------------- loop
    def run_once(self) -> bool:
        """One lease attempt; returns True if a job was processed."""
        job = self.client.lease_job(self.worker_id, queues=self.queues,
                                    ttl=self.lease_ttl,
                                    manifest=self.cache.manifest())
        if job is None:
            return False
        self.process(job)
        return True

    def run(self, stop: threading.Event) -> None:
        """Pull until ``stop`` is set.  Transport errors back off and
        retry — a worker outliving a head restart reconnects by itself.
        Auth failures are permanent (a bad or revoked token cannot heal
        by retrying), so they stop the agent loudly instead."""
        idle_wait = self.poll_interval
        while not stop.is_set():
            try:
                worked = self.run_once()
                idle_wait = self.poll_interval
            except AuthError as e:
                print(f"[{self.worker_id}] auth rejected by head, "
                      f"stopping: {e}", flush=True)
                return
            except (IDDSClientError, OSError) as e:
                self.transport_errors += 1
                self._log(f"transport error: {e}")
                worked = False
                # capped backoff so a dead head isn't hammered
                idle_wait = min(max(idle_wait * 2, self.poll_interval), 5.0)
            except Exception:  # pragma: no cover — agent resilience
                traceback.print_exc()
                worked = False
            if not worked:
                stop.wait(idle_wait)

    def stats(self) -> Dict[str, int]:
        return {"jobs_done": self.jobs_done,
                "jobs_failed": self.jobs_failed,
                "leases_lost": self.leases_lost,
                "transport_errors": self.transport_errors,
                "cached_contents": len(self.cache)}


class BatchWorkerAgent:
    """N payload slots behind one worker identity on the bulk protocol.

    Where a pool of :class:`WorkerAgent` runs one lease loop and one
    heartbeat thread *per slot*, the batch agent amortises the wire
    protocol: a single leaser grabs up to ``idle slots`` jobs per
    ``POST /jobs/lease?n=`` (one scheduler lock grab, one journal
    commit), and a single heartbeat thread renews every running lease
    with one ``POST /jobs/heartbeat`` per interval.  Per-item 409s in a
    batch response mark only that lease lost — the affected executor
    drops its job without reporting, exactly like the single-job agent,
    while the rest of the batch keeps running.
    """

    def __init__(self, url: str, *, concurrency: int = 2, token: str = "",
                 worker_id: Optional[str] = None,
                 queues: Optional[List[str]] = None,
                 lease_ttl: float = 30.0, poll_interval: float = 0.25,
                 client: Optional[IDDSClient] = None,
                 cache_capacity: int = 256,
                 verbose: bool = False):
        if concurrency < 1:
            raise ValueError("concurrency must be >= 1")
        self.worker_id = worker_id or default_worker_id()
        self.concurrency = int(concurrency)
        self.client = client if client is not None else \
            IDDSClient(url, token=token)
        self.queues = list(queues) if queues else None
        self.lease_ttl = lease_ttl
        self.poll_interval = poll_interval
        self.verbose = verbose
        self.cache = ContentCache(cache_capacity)
        self.jobs_done = 0
        self.jobs_failed = 0
        self.leases_lost = 0
        self.transport_errors = 0
        self._lock = threading.Lock()
        self._queue: "queue.Queue[Dict[str, Any]]" = queue.Queue()
        self._inflight = 0  # leased jobs queued or executing
        self._running: Dict[str, threading.Event] = {}  # job_id -> lost
        self._halt = threading.Event()  # internal stop (auth failure)

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(f"[{self.worker_id}] {msg}", flush=True)

    _execute = WorkerAgent._execute

    # ----------------------------------------------------------- executors
    def _process(self, job: Dict[str, Any]) -> bool:
        job_id = job["job_id"]
        lost = threading.Event()
        with self._lock:
            self._running[job_id] = lost
        self.cache.touch(list(job.get("input_files") or []))
        try:
            result, error = self._execute(job)
        finally:
            with self._lock:
                self._running.pop(job_id, None)
        if lost.is_set():
            with self._lock:
                self.leases_lost += 1
            self._log(f"lease lost mid-run for {job_id} (requeued by head)")
            return False
        try:
            self.client.complete_job(job_id, self.worker_id,
                                     result=result, error=error)
        except ConflictError:
            with self._lock:
                self.leases_lost += 1
            self._log(f"completion rejected for {job_id} (stale lease)")
            return False
        except (IDDSClientError, AuthError, OSError) as e:
            # the lease will expire and the head requeues; nothing more
            # this slot can do for the job
            with self._lock:
                self.transport_errors += 1
            self._log(f"completion failed for {job_id}: {e}")
            return False
        with self._lock:
            if error:
                self.jobs_failed += 1
            else:
                self.jobs_done += 1
        self._log(f"job {job_id} {'failed: ' + error if error else 'done'}")
        return True

    def _executor_loop(self, stop: threading.Event) -> None:
        # keeps draining already-leased jobs after stop so a graceful
        # shutdown completes what it holds instead of letting it expire
        while True:
            try:
                job = self._queue.get(timeout=0.05)
            except queue.Empty:
                if stop.is_set() or self._halt.is_set():
                    return
                continue
            try:
                self._process(job)
            except Exception:  # pragma: no cover — executor resilience
                traceback.print_exc()
            finally:
                with self._lock:
                    self._inflight -= 1

    # ----------------------------------------------------------- heartbeat
    def _heartbeat_loop(self, stop: threading.Event) -> None:
        interval = max(self.lease_ttl / 3.0, 0.02)
        while not self._halt.is_set():
            if stop.wait(interval):
                return
            with self._lock:
                snapshot = dict(self._running)
            if not snapshot:
                continue
            try:
                out = self.client.heartbeat_jobs(
                    list(snapshot), self.worker_id,
                    manifest=self.cache.manifest())
            except (IDDSClientError, AuthError, OSError) as e:
                # transient transport trouble: the leases may still be
                # live on the head — keep trying until they expire
                with self._lock:
                    self.transport_errors += 1
                self._log(f"batch heartbeat failed: {e}")
                continue
            for item in out.get("results", []):
                if not item.get("ok"):
                    ev = snapshot.get(item.get("job_id"))
                    if ev is not None:
                        ev.set()

    # ---------------------------------------------------------------- loop
    def run(self, stop: threading.Event) -> None:
        """Lease-in-batches until ``stop`` is set, then drain.  Transport
        errors back off and retry; auth failures stop the agent loudly
        (a bad token cannot heal by retrying)."""
        self._halt.clear()
        executors = [
            threading.Thread(target=self._executor_loop, args=(stop,),
                             name=f"{self.worker_id}-x{i}", daemon=True)
            for i in range(self.concurrency)
        ]
        for t in executors:
            t.start()
        hb = threading.Thread(target=self._heartbeat_loop, args=(stop,),
                              name=f"hb-{self.worker_id}", daemon=True)
        hb.start()
        idle_wait = self.poll_interval
        try:
            while not stop.is_set():
                with self._lock:
                    want = self.concurrency - self._inflight
                if want <= 0:
                    stop.wait(0.02)
                    continue
                try:
                    jobs = self.client.lease_jobs(
                        self.worker_id, want, queues=self.queues,
                        ttl=self.lease_ttl,
                        manifest=self.cache.manifest())
                    idle_wait = self.poll_interval
                except AuthError as e:
                    print(f"[{self.worker_id}] auth rejected by head, "
                          f"stopping: {e}", flush=True)
                    return
                except (IDDSClientError, OSError) as e:
                    with self._lock:
                        self.transport_errors += 1
                    self._log(f"transport error: {e}")
                    jobs = []
                    idle_wait = min(max(idle_wait * 2, self.poll_interval),
                                    5.0)
                except Exception:  # pragma: no cover — agent resilience
                    traceback.print_exc()
                    jobs = []
                if jobs:
                    with self._lock:
                        self._inflight += len(jobs)
                    for job in jobs:
                        self._queue.put(job)
                else:
                    stop.wait(idle_wait)
        finally:
            self._halt.set()
            for t in executors:
                t.join(timeout=10.0)
            hb.join(timeout=2.0)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"jobs_done": self.jobs_done,
                    "jobs_failed": self.jobs_failed,
                    "leases_lost": self.leases_lost,
                    "transport_errors": self.transport_errors,
                    "cached_contents": len(self.cache)}
