"""Pull-based worker agents for the iDDS distributed execution plane.

The paper's pilot/late-binding model: workers run anywhere, pull jobs
from the head service over the REST gateway (``POST /jobs/lease``),
execute the payload via the local payload registry, and report back —
the head never pushes work to a site it cannot reach.

  * :class:`~repro.worker.agent.WorkerAgent` — one lease → execute →
    report loop with background heartbeat renewal;
  * :class:`~repro.worker.pool.WorkerPool`   — N agents in one process;
  * ``python -m repro.worker``               — the worker CLI.
"""
from repro.worker.agent import WorkerAgent
from repro.worker.pool import WorkerPool

__all__ = ["WorkerAgent", "WorkerPool"]
