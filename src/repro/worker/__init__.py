"""Pull-based worker agents for the iDDS distributed execution plane.

The paper's pilot/late-binding model: workers run anywhere, pull jobs
from the head service over the REST gateway (``POST /jobs/lease``),
execute the payload via the local payload registry, and report back —
the head never pushes work to a site it cannot reach.

  * :class:`~repro.worker.agent.WorkerAgent` — one lease → execute →
    report loop with background heartbeat renewal;
  * :class:`~repro.worker.agent.BatchWorkerAgent` — N payload slots
    multiplexed over the bulk verbs (multi-lease + batch heartbeat);
  * :class:`~repro.worker.pool.WorkerPool`   — N slots in one process;
  * ``python -m repro.worker``               — the worker CLI.
"""
from repro.worker.agent import BatchWorkerAgent, WorkerAgent
from repro.worker.pool import WorkerPool

__all__ = ["BatchWorkerAgent", "WorkerAgent", "WorkerPool"]
