"""Multi-agent worker pool: N concurrent payload slots in one process.

Two wire strategies, selected by ``batch``:

* **batch** (default whenever ``concurrency > 1``): one
  :class:`~repro.worker.agent.BatchWorkerAgent` under a single
  worker_id multiplexes all slots over the bulk verbs — one
  multi-lease call feeds every idle slot and one heartbeat call renews
  every running lease, so head-side lock grabs and journal commits
  stay O(1) per interval instead of O(slots).
* **per-slot** (``batch=False`` or ``concurrency == 1``): one
  :class:`~repro.worker.agent.WorkerAgent` per slot, each with its own
  worker_id (``<base>-w<i>``) and its own lease/heartbeat loop — the
  pre-bulk protocol, kept for heterogeneous debugging and as the
  benchmark baseline.

Either way ``concurrency`` bounds how many payloads this process runs
at once and :meth:`WorkerPool.stats` aggregates the same counters.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

from repro.worker.agent import (BatchWorkerAgent, WorkerAgent,
                                default_worker_id)


class WorkerPool:
    def __init__(self, url: str, *, concurrency: int = 2,
                 worker_id: Optional[str] = None,
                 batch: Optional[bool] = None, **agent_kwargs):
        if concurrency < 1:
            raise ValueError("concurrency must be >= 1")
        base = worker_id or default_worker_id()
        self.batch = (concurrency > 1) if batch is None else bool(batch)
        if self.batch:
            self.agents = [BatchWorkerAgent(url, concurrency=concurrency,
                                            worker_id=base, **agent_kwargs)]
        else:
            self.agents = [
                WorkerAgent(url, worker_id=f"{base}-w{i}", **agent_kwargs)
                for i in range(concurrency)
            ]
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []

    def start(self) -> "WorkerPool":
        if self._threads:
            raise RuntimeError("pool already started")
        self._stop.clear()
        for agent in self.agents:
            t = threading.Thread(target=agent.run, args=(self._stop,),
                                 name=agent.worker_id, daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=timeout)
        self._threads.clear()

    def __enter__(self) -> "WorkerPool":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def stats(self) -> Dict[str, int]:
        """Aggregate counters across the pool's agents."""
        out: Dict[str, int] = {}
        for agent in self.agents:
            for k, v in agent.stats().items():
                out[k] = out.get(k, 0) + v
        return out
