"""Multi-agent worker pool: N concurrent lease loops in one process.

Each agent gets its own worker_id (``<base>-w<i>``) so the head's
worker registry and lease table see them as distinct pilots; payload
execution happens on the agent threads, so ``concurrency`` bounds how
many payloads this process runs at once.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

from repro.worker.agent import WorkerAgent, default_worker_id


class WorkerPool:
    def __init__(self, url: str, *, concurrency: int = 2,
                 worker_id: Optional[str] = None, **agent_kwargs):
        if concurrency < 1:
            raise ValueError("concurrency must be >= 1")
        base = worker_id or default_worker_id()
        self.agents: List[WorkerAgent] = [
            WorkerAgent(url, worker_id=f"{base}-w{i}", **agent_kwargs)
            for i in range(concurrency)
        ]
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []

    def start(self) -> "WorkerPool":
        if self._threads:
            raise RuntimeError("pool already started")
        self._stop.clear()
        for agent in self.agents:
            t = threading.Thread(target=agent.run, args=(self._stop,),
                                 name=agent.worker_id, daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=timeout)
        self._threads.clear()

    def __enter__(self) -> "WorkerPool":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def stats(self) -> Dict[str, int]:
        """Aggregate counters across the pool's agents."""
        out: Dict[str, int] = {}
        for agent in self.agents:
            for k, v in agent.stats().items():
                out[k] = out.get(k, 0) + v
        return out
