"""Worker CLI: run a pool of pull-based agents against a head service.

    PYTHONPATH=src python -m repro.worker --url http://127.0.0.1:8443 \
        --token s3cret --concurrency 4 --payloads my_payload_module

The process pulls jobs until SIGINT/SIGTERM, then drains its agents and
prints a summary.  Payload modules are imported locally (the head ships
payload *names*, never code), exactly like ``python -m repro.core.rest
--payloads`` on the head side.
"""
from __future__ import annotations

import argparse
import importlib
import signal
import threading

from repro.core.obs import setup_logging
from repro.worker.agent import default_worker_id
from repro.worker.pool import WorkerPool


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.worker",
        description="Pull-based payload worker for the iDDS execution "
                    "plane.")
    ap.add_argument("--url", required=True,
                    help="head-service gateway, e.g. http://host:8443")
    ap.add_argument("--token", default="",
                    help="bearer token (omit if the head runs auth-off)")
    ap.add_argument("--concurrency", type=int, default=2,
                    help="agents (= concurrent payloads) in this process")
    ap.add_argument("--queues", default=None,
                    help="comma-separated queue names to pull from "
                         "(omit = all queues)")
    ap.add_argument("--lease-ttl", type=float, default=30.0,
                    help="requested lease seconds between heartbeats")
    ap.add_argument("--poll-interval", type=float, default=0.25,
                    help="idle seconds between empty lease attempts")
    ap.add_argument("--worker-id", default=None,
                    help="worker id base (default: host-pid); agents "
                         "append -w<i>")
    ap.add_argument("--no-batch", action="store_true",
                    help="use one lease/heartbeat loop per slot instead "
                         "of the bulk verbs (pre-bulk wire protocol)")
    ap.add_argument("--payloads", action="append", default=[],
                    help="importable module that registers payloads "
                         "(repeatable)")
    ap.add_argument("--verbose", action="store_true",
                    help="log each job")
    ap.add_argument("--log-level", default="INFO",
                    choices=("DEBUG", "INFO", "WARNING", "ERROR"),
                    help="threshold for the structured core logs")
    ap.add_argument("--log-json", action="store_true",
                    help="emit core logs as one JSON object per line "
                         "(for log shippers) instead of text")
    args = ap.parse_args(argv)

    for mod in args.payloads:
        importlib.import_module(mod)

    queues = ([q for q in args.queues.split(",") if q]
              if args.queues else None)
    base = args.worker_id or default_worker_id()
    setup_logging(args.log_level, args.log_json, base)
    pool = WorkerPool(args.url, concurrency=args.concurrency,
                      worker_id=base, token=args.token, queues=queues,
                      batch=False if args.no_batch else None,
                      lease_ttl=args.lease_ttl,
                      poll_interval=args.poll_interval,
                      verbose=args.verbose)

    stop_evt = threading.Event()

    def _on_signal(signum, frame):
        stop_evt.set()

    signal.signal(signal.SIGINT, _on_signal)
    signal.signal(signal.SIGTERM, _on_signal)

    pool.start()
    print(f"worker {base} pulling from {args.url} "
          f"(concurrency={args.concurrency}, "
          f"queues={','.join(queues) if queues else 'all'})", flush=True)
    try:
        stop_evt.wait()
        print(f"worker {base}: signal received, draining", flush=True)
    finally:
        pool.stop()
        print(f"worker {base} stopped: {pool.stats()}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
