"""Serving steps: prefill (fill cache from a prompt) and decode (one token).

``decode_*`` shapes in the assignment lower ``decode_step`` — one new token
against a KV cache of seq_len — NOT a train step.  Caches are dict pytrees
built from ParamDefs, so the dry-run gets abstract caches and the sharding
rules shard them (batch on data axis, heads/kv_seq on model axis) exactly
like params.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import params as P
from repro.models import registry

Cache = Dict[str, Any]


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    return registry.cache_defs(cfg, batch, max_len)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    defs = cache_defs(cfg, batch, max_len)
    return P.tree_map(lambda d: jnp.zeros(d.shape, d.dtype), defs)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    return P.abstract(cache_defs(cfg, batch, max_len))


def prefill_step(params, batch: Dict[str, Any], cache: Cache, *,
                 cfg: ModelConfig, run: RunConfig
                 ) -> Tuple[jax.Array, Cache]:
    """Prompt (B, S) -> (next-token ids (B, 1), filled cache)."""
    logits, cache = registry.prefill(params, cfg, run, batch, cache)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return next_tok, cache


def decode_step(params, tokens: jax.Array, cache: Cache, pos, *,
                cfg: ModelConfig, run: RunConfig
                ) -> Tuple[jax.Array, Cache]:
    """One greedy decode step. tokens: (B, 1) ids; pos: scalar length."""
    logits, cache = registry.decode(params, cfg, run, tokens, cache, pos)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return next_tok, cache


def make_prefill_step(cfg: ModelConfig, run: RunConfig):
    return functools.partial(prefill_step, cfg=cfg, run=run)


def make_decode_step(cfg: ModelConfig, run: RunConfig):
    return functools.partial(decode_step, cfg=cfg, run=run)
