from repro.serve.engine import (  # noqa: F401
    abstract_cache,
    decode_step,
    init_cache,
    make_decode_step,
    make_prefill_step,
    prefill_step,
)
