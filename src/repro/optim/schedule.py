"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, base_lr: float, warmup_steps: int,
                    total_steps: int, min_ratio: float = 0.1):
    """Linear warmup then cosine decay to ``min_ratio * base_lr``."""
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(warmup_steps, 1), 1.0)
    prog = jnp.clip((s - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = min_ratio + (1.0 - min_ratio) * cos
    return base_lr * warm * decay
