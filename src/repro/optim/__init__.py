from repro.optim.adamw import (  # noqa: F401
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedule import cosine_schedule  # noqa: F401
