"""AdamW with decoupled weight decay, global-norm clipping and optional
bf16 first-moment compression (distributed-optimization trick: halves the
optimizer-state HBM footprint and the bytes moved per step).

No optax dependency — state is a plain dict pytree so the checkpointer
and the sharding rules treat it exactly like params (optimizer state is
sharded identically to its parameter: ZeRO-style).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

OptState = Dict[str, Any]


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def adamw_init(params: Any, *, dtype=jnp.float32) -> OptState:
    """m/v moments shaped like params. ``dtype`` compresses the moments."""
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params: Any,
    grads: Any,
    state: OptState,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if max_grad_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = global_norm(grads)

    step = state["step"] + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        pf = p.astype(jnp.float32)
        # decoupled weight decay; skip 1-D params (norms / biases)
        if p.ndim >= 2:
            pf = pf - lr * weight_decay * pf
        p_new = (pf - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])

    new_state = {"m": new_m, "v": new_v, "step": step}
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, new_state, metrics
