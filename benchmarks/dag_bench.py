"""Benchmark: Rubin-scale DAG scheduling (paper §3.3.1).

'A single workflow can consist of a hundred thousand jobs forming the
vertexes of a DAG ... Work objects incrementally released based on
messaging.'  Measures end-to-end scheduling throughput (jobs/s through
the full Clerk->...->Conductor machinery) at 10^3..10^5 vertices.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.dag import DAGScheduler, layered_dag
from repro.core.idds import IDDS


def run(sizes=(1_000, 10_000, 100_000)) -> List[Dict]:
    rows = []
    for n in sizes:
        jobs = layered_dag(n, width=max(100, n // 100), fan_in=3, seed=0)
        idds = IDDS()
        sched = DAGScheduler(idds, jobs)
        t0 = time.time()
        out = sched.run_sync()
        wall = time.time() - t0
        rows.append({
            "jobs": n,
            "wall_s": round(wall, 2),
            "jobs_per_s": round(n / wall),
            "released": out["released"],
            "pump_rounds": out["rounds"],
            "us_per_job": round(1e6 * wall / n, 1),
        })
    return rows


def main():
    rows = run()
    keys = ["jobs", "wall_s", "jobs_per_s", "released", "pump_rounds",
            "us_per_job"]
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))


if __name__ == "__main__":
    main()
