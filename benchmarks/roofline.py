"""Roofline table from the dry-run sweep (EXPERIMENTS.md §Roofline).

Reads dryrun_results.json (produced by ``python -m repro.launch.dryrun
--all --both-meshes --out dryrun_results.json``) and prints the per-cell
three-term roofline for the single-pod mesh.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "dryrun_results.json")


def load(path: str = DEFAULT_PATH) -> List[Dict]:
    with open(path) as f:
        return json.load(f)


def table(results: List[Dict], mesh_chips: int = 256) -> List[Dict]:
    rows = []
    for r in results:
        if r.get("status") != "ok" or r.get("chips") != mesh_chips:
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_ms": round(rf["compute_s"] * 1e3, 2),
            "memory_ms": round(rf["memory_s"] * 1e3, 2),
            "collective_ms": round(rf["collective_s"] * 1e3, 2),
            "dominant": rf["dominant"].replace("_s", ""),
            "bound_ms": round(rf["bound_s"] * 1e3, 2),
            "compute_fraction": round(rf["compute_fraction"], 3),
            "useful_flops_ratio": round(rf["useful_flops_ratio"], 3),
        })
    return rows


def main(path: str = None):
    path = path or DEFAULT_PATH
    if not os.path.exists(path):
        print(f"no dry-run results at {path}; run the dryrun sweep first",
              file=sys.stderr)
        return
    rows = table(load(path))
    keys = ["arch", "shape", "compute_ms", "memory_ms", "collective_ms",
            "dominant", "bound_ms", "compute_fraction",
            "useful_flops_ratio"]
    print(",".join(keys))
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        print(",".join(str(r[k]) for k in keys))


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else None)
