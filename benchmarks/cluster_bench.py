"""Benchmark: aggregate head-service throughput at 1 vs 2+ heads.

The multi-head deployment exists so the service scales horizontally:
several ``repro.core.rest`` heads pump ONE shared catalog over the
store-polling bus, partitioning work through the workflow-claim CAS.
This bench boots N in-process heads on one shared store, splits a
client fleet across them, and measures aggregate submissions/sec plus
the drain to every workflow finishing — the cluster must not lose or
double-process anything while it scales.

    PYTHONPATH=src python -m benchmarks.cluster_bench [--smoke]
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import Dict, List

from repro.core.client import IDDSClient
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.store import InMemoryStore

KEYS = ["heads", "clients", "submissions", "sub_wall_s",
        "agg_sub_per_s", "drain_wall_s", "finished"]


def _make_request_json() -> str:
    from repro.core.requests import Request
    from repro.core.spec import WorkflowSpec
    spec = WorkflowSpec("cluster-bench")
    spec.work("n", payload="noop", start={})
    return Request(workflow=spec.build()).to_json()


def run_one(n_heads: int, *, clients_per_head: int = 4,
            per_client: int = 10) -> Dict:
    """N heads on one shared catalog; clients pinned per head submit
    concurrently; then the cluster drains every workflow to finished."""
    store = InMemoryStore()
    heads = [IDDS(store=store, bus="store",
                  head_id=f"bench-head-{k}", claim_ttl=5.0)
             for k in range(n_heads)]
    gws = [RestGateway(h) for h in heads]
    for gw in gws:
        gw.start()
    try:
        n_clients = n_heads * clients_per_head
        rids: List[List[str]] = [[] for _ in range(n_clients)]
        errors: List[Exception] = []
        barrier = threading.Barrier(n_clients)

        def submitter(i: int):
            try:
                client = IDDSClient(gws[i % n_heads].url)
                barrier.wait()
                for _ in range(per_client):
                    rids[i].append(client.submit(_make_request_json()))
            except Exception as e:  # noqa: BLE001 — surfaced below
                errors.append(e)

        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(n_clients)]
        t0 = time.time()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        sub_wall = time.time() - t0
        assert not errors, errors

        # drain: every workflow must finish somewhere in the cluster;
        # any head answers status polls (catalog fallback for
        # workflows a peer owns)
        client = IDDSClient(gws[0].url)
        t1 = time.time()
        finished = 0
        for per in rids:
            for rid in per:
                if client.wait(rid, timeout=120)["status"] == "finished":
                    finished += 1
        drain_wall = time.time() - t1
        n_sub = n_clients * per_client
        return {
            "heads": n_heads,
            "clients": n_clients,
            "submissions": n_sub,
            "sub_wall_s": round(sub_wall, 3),
            "agg_sub_per_s": round(n_sub / sub_wall),
            "drain_wall_s": round(drain_wall, 3),
            "finished": finished,
        }
    finally:
        for gw in gws:
            gw.stop()
        store.close()


def run(head_counts=(1, 2), *, clients_per_head: int = 4,
        per_client: int = 10) -> List[Dict]:
    return [run_one(n, clients_per_head=clients_per_head,
                    per_client=per_client) for n in head_counts]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", "--smoke", action="store_true",
                    dest="quick", help="fewer submissions per client (CI)")
    args = ap.parse_args(argv)
    rows = run(per_client=5 if args.quick else 10)
    print(",".join(KEYS))
    for r in rows:
        print(",".join(str(r[k]) for k in KEYS))


if __name__ == "__main__":
    main()
