"""Benchmark: HPO service (paper Fig. 6).

(1) optimizer quality: best objective found per budget, random vs halton
    vs evolution on two synthetic objectives;
(2) async speedup: wall time with 1 vs 8 remote 'GPU sites' for the same
    trial budget (the service's whole point: asynchronous evaluation on
    distributed resources).
"""
from __future__ import annotations

import math
import time
from typing import Dict, List

from repro.core import payloads as reg
from repro.core.hpo import HPOService, OPTIMIZERS, uniform
from repro.core.idds import IDDS


def _branin(params, inputs):
    x = params["x"] * 15 - 5
    y = params["y"] * 15
    a, b, c = 1.0, 5.1 / (4 * math.pi ** 2), 5 / math.pi
    r, s, t = 6.0, 10.0, 1 / (8 * math.pi)
    val = a * (y - b * x * x + c * x - r) ** 2 + s * (1 - t) * math.cos(x) + s
    return {"objective": val}


def _rosenbrock(params, inputs):
    x, y = params["x"] * 4 - 2, params["y"] * 4 - 2
    return {"objective": (1 - x) ** 2 + 100 * (y - x * x) ** 2}


reg.register_payload("bench_branin", _branin)
reg.register_payload("bench_rosen", _rosenbrock)


def quality(budget: int = 64) -> List[Dict]:
    rows = []
    for obj_name, payload in (("branin", "bench_branin"),
                              ("rosenbrock", "bench_rosen")):
        for opt in OPTIMIZERS:
            bests = []
            for seed in range(3):
                idds = IDDS()
                svc = HPOService(
                    idds, {"x": uniform(0, 1), "y": uniform(0, 1)},
                    eval_payload=payload, optimizer=opt,
                    points_per_round=8, max_points=budget, seed=seed)
                bests.append(svc.run().best_objective)
            rows.append({"objective": obj_name, "optimizer": opt,
                         "budget": budget,
                         "best_mean": sum(bests) / len(bests),
                         "best_min": min(bests)})
    return rows


def async_speedup(budget: int = 32, trial_s: float = 0.02) -> List[Dict]:
    reg.register_payload(
        "bench_slow",
        lambda p, i: (time.sleep(trial_s), _branin(p, i))[1])
    rows = []
    for workers in (1, 8):
        idds = IDDS(sync=False, max_workers=workers)
        idds.start()
        try:
            svc = HPOService(idds, {"x": uniform(0, 1), "y": uniform(0, 1)},
                             eval_payload="bench_slow", optimizer="halton",
                             points_per_round=8, max_points=budget, seed=0)
            t0 = time.time()
            svc.run(timeout=120)
            wall = time.time() - t0
        finally:
            idds.stop()
        rows.append({"workers": workers, "budget": budget,
                     "wall_s": round(wall, 3),
                     "trials_per_s": round(budget / wall, 1)})
    rows.append({"workers": "speedup",
                 "wall_s": round(rows[0]["wall_s"] / rows[1]["wall_s"], 2)})
    return rows


def main():
    print("objective,optimizer,budget,best_mean,best_min")
    for r in quality():
        print(f"{r['objective']},{r['optimizer']},{r['budget']},"
              f"{r['best_mean']:.4f},{r['best_min']:.4f}")
    print("workers,budget,wall_s,trials_per_s")
    for r in async_speedup():
        print(",".join(str(r.get(k, "")) for k in
                       ("workers", "budget", "wall_s", "trials_per_s")))


if __name__ == "__main__":
    main()
