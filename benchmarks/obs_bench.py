"""Benchmark: telemetry overhead — metrics/tracing on vs off.

The telemetry plane (repro.core.obs) promises to be cheap enough to
stay on in every hot path.  This bench puts a number on that promise:

  e2e-metrics   full head service (submit + pump through every daemon)
                with the metrics registry enabled vs ``telemetry=False``
                no-op instruments; tracing disabled in BOTH arms so the
                delta is the registry alone.  This is the <=5% gate.
  e2e-full      same run with metrics AND lifecycle tracing on vs all
                off — the informational "everything" number (tracing
                journals rows through the store, so it costs more than
                counters).
  store-write   content-journal writes through ``save_many`` (the
                journal path every daemon flush takes — the verb that
                carries the write histogram/counter) with metrics
                bound vs unbound.
  sched-loop    the worker-path hot loop — enqueue, lease, complete
                through the JobScheduler (lease journaling through the
                store, as a head under ``--distributed`` runs it) with
                the scheduler's op/duration histograms on vs off.
  instrument    raw per-op cost of one counter inc / histogram observe
                and the no-op child they degrade to when disabled.

Measurement discipline: shared-box noise (steal time, frequency
scaling) easily exceeds the few-percent overhead being measured, so
each arm runs many SHORT off/on pairs in strict alternation — the two
arms of a pair see the same instantaneous machine state, and the pair
period is far shorter than typical load bursts — then reports the
median of the per-pair on/off ratios.  Each sample is additionally the
MIN of a few inner repetitions (timeit-style: the minimum is the
least-interrupted run), and the GC is disabled inside each sample
(collecting first) so a collection triggered by one arm's garbage
can't land in the other arm's wall.  A null calibration (both arms
identical) sits within about +-2-4% under this scheme; overheads are
read against that floor.

    PYTHONPATH=src python -m benchmarks.obs_bench [--smoke]
"""
from __future__ import annotations

import argparse
import gc
import statistics
import time
from typing import Callable, Dict, List, Tuple

from repro.core.idds import IDDS
from repro.core.obs import MetricsRegistry
from repro.core.requests import Request
from repro.core.scheduler import JobScheduler
from repro.core.spec import WorkflowSpec
from repro.core.store import InMemoryStore
from repro.core.workflow import FileRef, Processing

KEYS = ["arm", "telemetry", "n", "wall_s", "per_s", "overhead_pct"]


def _make_request_json() -> str:
    spec = WorkflowSpec("obs-bench")
    spec.work("n", payload="noop", start={})
    return Request(workflow=spec.build()).to_json()


def _e2e_wall(n: int, *, metrics: bool, tracing: bool) -> float:
    """Submit+pump wall seconds for n one-work noop workflows."""
    idds = IDDS(store=InMemoryStore(), telemetry=metrics)
    idds.tracer.enabled = tracing
    payloads = [_make_request_json() for _ in range(n)]  # not timed
    t0 = time.perf_counter()
    for p in payloads:
        idds.submit(p)
    idds.pump()
    wall = time.perf_counter() - t0
    idds.close()
    return wall


def _store_write_wall(n_rows: int, batch: int, *, metrics: bool) -> float:
    """Journal ``n_rows`` content rows through ``save_many`` — the verb
    every BufferedStore flush and daemon journal commit lands on, and
    the one that carries the store write histogram/counter."""
    files = [FileRef(f"f{i}", size=i, available=True).to_dict()
             for i in range(n_rows)]
    store = InMemoryStore()
    if metrics:
        store.bind_metrics(MetricsRegistry(head_id="bench"))
    ops = [[("contents", (f"c{i // batch}", files[i:i + batch]))]
           for i in range(0, n_rows, batch)]
    t0 = time.perf_counter()
    for op in ops:
        store.save_many(op)
    return time.perf_counter() - t0


def _sched_wall(n_jobs: int, *, metrics: bool, batch: int = 16) -> float:
    """Enqueue + lease + complete n_jobs through the JobScheduler —
    the loop a ``--distributed`` head runs per worker pull, in the
    worker pool's default bulk wire mode (lease_many/complete_many)."""
    sched = JobScheduler(default_ttl=600.0)
    sched.attach(InMemoryStore(),
                 metrics=(MetricsRegistry(head_id="bench")
                          if metrics else None))
    procs = [Processing(proc_id=f"p{i}", work_id="w", payload="noop",
                        params={}) for i in range(n_jobs)]
    t0 = time.perf_counter()
    for p in procs:
        sched.enqueue(p)
    while True:
        jobs = sched.lease_many("bench-worker", n=batch)
        if not jobs:
            break
        sched.complete_many("bench-worker",
                            [(j["job_id"], {}, None) for j in jobs])
    return time.perf_counter() - t0


def _timed(fn: Callable[[], float], reps: int = 3) -> float:
    """One sample: the MIN of ``reps`` back-to-back runs (the
    least-interrupted one), with the GC parked for the duration."""
    gc.collect()
    gc.disable()
    try:
        return min(fn() for _ in range(reps))
    finally:
        gc.enable()


def _paired(fn_off: Callable[[], float], fn_on: Callable[[], float],
            pairs: int, reps: int = 3) -> Tuple[float, float, float]:
    """(median off wall, median on wall, median per-pair on/off ratio)
    over ``pairs`` strictly-alternating off/on samples; which arm goes
    first flips each pair so ramping load cancels."""
    offs, ons, ratios = [], [], []
    for k in range(pairs):
        if k % 2:
            on = _timed(fn_on, reps)
            off = _timed(fn_off, reps)
        else:
            off = _timed(fn_off, reps)
            on = _timed(fn_on, reps)
        offs.append(off)
        ons.append(on)
        ratios.append(on / off)
    return (statistics.median(offs), statistics.median(ons),
            statistics.median(ratios))


def _pair_rows(arm: str, n: int, off_wall: float, on_wall: float,
               ratio: float) -> List[Dict]:
    return [
        {"arm": arm, "telemetry": "off", "n": n,
         "wall_s": round(off_wall, 4), "per_s": round(n / off_wall)},
        {"arm": arm, "telemetry": "on", "n": n,
         "wall_s": round(on_wall, 4), "per_s": round(n / on_wall),
         "overhead_pct": round((ratio - 1.0) * 100.0, 2)},
    ]


def _instrument_rows(ops: int) -> List[Dict]:
    reg_on = MetricsRegistry(head_id="bench")
    reg_off = MetricsRegistry(head_id="bench", enabled=False)
    rows = []
    for name, child in (
            ("counter-inc", reg_on.counter("bench_ops").labels()),
            ("histogram-observe",
             reg_on.histogram("bench_lat").labels()),
            ("noop-disabled", reg_off.counter("bench_ops").labels())):
        op = child.observe if name == "histogram-observe" else child.inc
        t0 = time.perf_counter()
        for _ in range(ops):
            op(0.001)
        wall = time.perf_counter() - t0
        rows.append({"arm": f"instrument-{name}", "telemetry": "on",
                     "n": ops, "wall_s": round(wall, 4),
                     "per_s": round(ops / wall)})
    return rows


def run(n: int = 50, write_rows: int = 2000, write_batch: int = 256,
        pairs: int = 40, instrument_ops: int = 200_000) -> List[Dict]:
    rows: List[Dict] = []
    off, on, r = _paired(
        lambda: _e2e_wall(n, metrics=False, tracing=False),
        lambda: _e2e_wall(n, metrics=True, tracing=False), pairs)
    rows += _pair_rows("e2e-metrics", n, off, on, r)
    off, full, r = _paired(
        lambda: _e2e_wall(n, metrics=False, tracing=False),
        lambda: _e2e_wall(n, metrics=True, tracing=True), pairs)
    rows += _pair_rows("e2e-full", n, off, full, r)[1:]
    woff, won, r = _paired(
        lambda: _store_write_wall(write_rows, write_batch,
                                  metrics=False),
        lambda: _store_write_wall(write_rows, write_batch,
                                  metrics=True), pairs)
    rows += _pair_rows("store-write", write_rows, woff, won, r)
    n_jobs = max(write_rows // 4, 250)  # floor: a 2ms wall is all noise
    soff, son, r = _paired(
        lambda: _sched_wall(n_jobs, metrics=False),
        lambda: _sched_wall(n_jobs, metrics=True), pairs)
    rows += _pair_rows("sched-loop", n_jobs, soff, son, r)
    rows += _instrument_rows(instrument_ops)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", action="store_true",
                    dest="smoke", help="fewer, smaller samples (CI)")
    args = ap.parse_args(argv)
    rows = (run(n=30, write_rows=500, pairs=12, instrument_ops=50_000)
            if args.smoke else run())
    print(",".join(KEYS))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in KEYS))


if __name__ == "__main__":
    main()
