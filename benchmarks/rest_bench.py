"""Benchmark: REST gateway throughput + status-poll latency.

Measures the network boundary the paper's head service must sustain
("heavy traffic from many clients"): N concurrent IDDSClients submitting
single-work workflows as fast as they can, then hammering status polls
against the live gateway.  Reports submissions/sec and p50/p95 poll
latency per client count, in the same keys-header-then-CSV-rows shape as
the other benchmarks driven by benchmarks/run.py.

    PYTHONPATH=src python -m benchmarks.rest_bench [--quick]
"""
from __future__ import annotations

import argparse
import threading
import time
from typing import Dict, List

from repro.core.client import IDDSClient
from repro.core.idds import IDDS
from repro.core.rest import RestGateway

KEYS = ["clients", "submissions", "sub_wall_s", "sub_per_s",
        "polls", "poll_p50_ms", "poll_p95_ms", "finished"]


def _percentile(xs: List[float], p: float) -> float:
    xs = sorted(xs)
    if not xs:
        return 0.0
    k = min(len(xs) - 1, max(0, int(round(p * (len(xs) - 1)))))
    return xs[k]


def run_one(n_clients: int, *, per_client: int = 25,
            polls_per_client: int = 50) -> Dict:
    with RestGateway(IDDS()) as gw:
        rids_per_client: List[List[str]] = [[] for _ in range(n_clients)]
        poll_lat: List[List[float]] = [[] for _ in range(n_clients)]
        errors: List[Exception] = []
        barrier = threading.Barrier(n_clients)

        def submitter(i: int):
            try:
                client = IDDSClient(gw.url)
                barrier.wait()
                for _ in range(per_client):
                    # fresh request (new request_id + workflow_id) per submit
                    rids_per_client[i].append(
                        client.submit(_make_request_json()))
            except Exception as e:  # noqa: BLE001 — surfaced below
                errors.append(e)

        def poller(i: int):
            try:
                client = IDDSClient(gw.url)
                rids = rids_per_client[i]
                for k in range(polls_per_client):
                    t0 = time.perf_counter()
                    client.status(rids[k % len(rids)])
                    poll_lat[i].append(time.perf_counter() - t0)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        # phase 1: concurrent submissions
        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(n_clients)]
        t0 = time.time()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        sub_wall = time.time() - t0
        assert not errors, errors

        # phase 2: concurrent status polls against the live gateway
        threads = [threading.Thread(target=poller, args=(i,))
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors

        # drain: every submitted workflow must complete
        client = IDDSClient(gw.url)
        finished = 0
        for rids in rids_per_client:
            for rid in rids:
                if client.wait(rid, timeout=60)["status"] == "finished":
                    finished += 1

        lats = [x for per in poll_lat for x in per]
        n_sub = n_clients * per_client
        return {
            "clients": n_clients,
            "submissions": n_sub,
            "sub_wall_s": round(sub_wall, 3),
            "sub_per_s": round(n_sub / sub_wall),
            "polls": len(lats),
            "poll_p50_ms": round(_percentile(lats, 0.50) * 1e3, 2),
            "poll_p95_ms": round(_percentile(lats, 0.95) * 1e3, 2),
            "finished": finished,
        }


def _make_request_json() -> str:
    from repro.core.requests import Request
    from repro.core.spec import WorkflowSpec
    spec = WorkflowSpec("bench")
    spec.work("n", payload="noop", start={})
    return Request(workflow=spec.build()).to_json()


def run(client_counts=(1, 4, 8), *, per_client: int = 25,
        polls_per_client: int = 50) -> List[Dict]:
    rows = []
    for n in client_counts:
        rows.append(run_one(n, per_client=per_client,
                            polls_per_client=polls_per_client))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", "--smoke", action="store_true",
                    dest="quick", help="fewer submissions per client (CI)")
    args = ap.parse_args(argv)
    per = 10 if args.quick else 25
    rows = run(per_client=per)
    print(",".join(KEYS))
    for r in rows:
        print(",".join(str(r[k]) for k in KEYS))


if __name__ == "__main__":
    main()
