"""Benchmark: Data Carousel fine vs coarse granularity (paper Figs. 4-5).

Reproduces the paper's bulk-reprocessing comparison at three campaign
scales.  Columns map to the paper's claims:
  attempts_per_job  -> Fig. 4 'iDDS reduces a lot of job attempts'
  peak_disk_TB      -> Fig. 5 'minimize the input data footprint on disk'
  ttfp_h            -> 'starts processing as soon as data appears from tape'
  makespan_h        -> end-to-end campaign time (no regression)
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.carousel.simulator import compare

CAMPAIGNS = {
    "small-500f": dict(n_files=500, disk_capacity=1.2e12),
    "mid-2000f": dict(n_files=2000, disk_capacity=2e12),
    "large-10000f": dict(n_files=10000, disk_capacity=8e12,
                         n_workers=400, n_drives=16),
}


def run(csv: bool = False) -> List[Dict]:
    rows = []
    for name, kw in CAMPAIGNS.items():
        t0 = time.time()
        out = compare(hedge=True, seed=0, **kw)
        dt = time.time() - t0
        for mode in ("coarse", "fine"):
            r = out[mode]
            rows.append({"campaign": name, "mode": mode, **r,
                         "sim_wall_s": round(dt, 2)})
    # headline ratios (the paper's Fig. 4/5 deltas)
    for name in CAMPAIGNS:
        c = next(r for r in rows if r["campaign"] == name
                 and r["mode"] == "coarse")
        f = next(r for r in rows if r["campaign"] == name
                 and r["mode"] == "fine")
        rows.append({
            "campaign": name, "mode": "ratio(coarse/fine)",
            "job_attempts": round(c["job_attempts"] / f["job_attempts"], 2),
            "peak_disk_TB": round(c["peak_disk_TB"] / f["peak_disk_TB"], 2),
            "ttfp_h": round(c["ttfp_h"] / max(f["ttfp_h"], 1e-9), 1),
            "makespan_h": round(c["makespan_h"] / f["makespan_h"], 2),
        })
    return rows


def main():
    rows = run()
    keys = ["campaign", "mode", "job_attempts", "attempts_per_job",
            "failed_attempts", "peak_disk_TB", "disk_TB_hours", "ttfp_h",
            "makespan_h"]
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))


if __name__ == "__main__":
    main()
