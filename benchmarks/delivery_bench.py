"""Benchmark: the content delivery plane.

(1) time-to-first-delivery, fine vs coarse: the same staged corpus
    consumed by a ``DeliveryIterator`` in both granularities — fine
    starts on the first landed shard, coarse blocks for the whole
    collection (the paper's Fig. 4/5 effect, at the delivery layer);
(2) content journaling throughput: content rows/s sustained through
    ``Store.save_contents`` on both backends (the per-file state
    machine's hot path), one row per call vs ~256-row batches (the
    bulk path daemons reach through the write-coalescing buffer).

    PYTHONPATH=src python -m benchmarks.delivery_bench [--smoke]
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time
from typing import Dict, List

from repro.carousel.delivery import DeliveryIterator
from repro.carousel.stager import Stager
from repro.carousel.storage import DiskCache
from repro.carousel.transform import make_packing_transform
from repro.core.store import InMemoryStore, SqliteStore
from repro.core.workflow import FileRef
from repro.data.synthetic import build_cold_store

KEYS = ["mode", "n_shards", "ttfd_ms", "total_ms", "rows", "batches",
        "failed_shards", "contents_per_s"]

SEQ = 64


def _deliver(n_shards: int, coarse: bool, *, latency: float) -> Dict:
    # one tape drive: shards land serially, so the fine/coarse gap in
    # time-to-first-delivery is the paper's effect, not thread noise
    cold = build_cold_store(n_shards=n_shards, docs_per_shard=16,
                            vocab_size=512, mean_doc_len=SEQ, drives=1,
                            mount_latency=latency)
    cache = DiskCache(1 << 30)
    names = [f.name for f in cold.files()]
    st = Stager(cold, cache, workers=4,
                transform=make_packing_transform(SEQ))
    st.submit_all(names)
    it = DeliveryIterator(st, cache, names, batch_rows=4, coarse=coarse)
    n_batches = sum(1 for _ in it)
    st.shutdown()
    return {
        "mode": "coarse" if coarse else "fine",
        "n_shards": n_shards,
        "ttfd_ms": round(1e3 * (it.first_batch_at - it.started_at), 1),
        "total_ms": round(
            1e3 * (time.monotonic() - it.started_at), 1),
        "rows": it.rows_delivered,
        "batches": n_batches,
        "failed_shards": it.failed_shards,
    }


def _journal(store, label: str, n_contents: int, batch: int = 1) -> Dict:
    rows = [FileRef(f"f{i}", size=i, available=True).to_dict()
            for i in range(n_contents)]
    t0 = time.monotonic()
    if batch <= 1:
        # one row per call: the state-transition pattern
        for r in rows:
            store.save_contents("bench", [r])
    else:
        # coalesced batches: one transaction per `batch` rows
        for i in range(0, n_contents, batch):
            store.save_contents("bench", rows[i:i + batch])
    wall = time.monotonic() - t0
    store.close()
    suffix = "-bulk" if batch > 1 else ""
    return {"mode": f"journal-{label}{suffix}", "rows": n_contents,
            "total_ms": round(1e3 * wall, 1),
            "contents_per_s": round(n_contents / wall, 1)}


def run(*, n_shards: int = 12, latency: float = 0.01,
        n_contents: int = 2000) -> List[Dict]:
    out = []
    for coarse in (False, True):
        out.append(_deliver(n_shards, coarse, latency=latency))
    d = tempfile.mkdtemp(prefix="idds_dlv_")
    out.append(_journal(InMemoryStore(), "memory", n_contents))
    out.append(_journal(SqliteStore(os.path.join(d, "one.db")),
                        "sqlite", n_contents))
    out.append(_journal(InMemoryStore(), "memory", n_contents, batch=256))
    out.append(_journal(SqliteStore(os.path.join(d, "bulk.db")),
                        "sqlite", n_contents, batch=256))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI")
    args = ap.parse_args(argv)
    rows = (run(n_shards=6, latency=0.02, n_contents=300)
            if args.smoke else run())
    print(",".join(KEYS))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in KEYS))
    fine, coarse = rows[0], rows[1]
    assert fine["rows"] == coarse["rows"], (fine, coarse)
    speedup = coarse["ttfd_ms"] / max(fine["ttfd_ms"], 0.1)
    print(f"\nfine starts {speedup:.1f}x earlier than coarse "
          f"({fine['ttfd_ms']}ms vs {coarse['ttfd_ms']}ms to first "
          f"delivery)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
