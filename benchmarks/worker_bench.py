"""Benchmark: distributed execution plane throughput + lease overhead.

Measures end-to-end jobs/sec through the full stack — REST submit,
daemon dispatch, lease scheduler, worker pool pulling over HTTP — as
the worker count scales, plus the lease-renewal (heartbeat) round-trip
cost a worker pays while executing.  The jobs are fixed-duration
``sleep_ms`` payloads, so jobs/sec rising with worker count is the
execution plane actually parallelizing, not a faster payload.

Pools run in two wire modes: ``batch`` (one multi-lease + one batch
heartbeat for the whole pool — the default) and ``per-slot`` (one
lease/heartbeat loop per slot, the pre-bulk baseline); at 8-16 workers
the batch rows should beat the per-slot rows.

    PYTHONPATH=src python -m benchmarks.worker_bench [--smoke]
"""
from __future__ import annotations

import argparse
import statistics
import time
from typing import Dict, List

from repro.core.client import IDDSClient
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.scheduler import DistributedWFM
from repro.core.spec import WorkflowSpec
from repro.core.workflow import Processing, Workflow
from repro.worker import WorkerPool

KEYS = ["workers", "mode", "jobs", "sleep_ms", "wall_s", "jobs_per_s",
        "hb_p50_ms", "hb_p95_ms"]


def _workflow(n_jobs: int, sleep_ms: float) -> Workflow:
    spec = WorkflowSpec("worker-bench")
    spec.work("s", payload="sleep_ms", defaults={"ms": sleep_ms},
              start=[{} for _ in range(n_jobs)])
    return spec.build()


def throughput(worker_counts=(1, 2, 4), jobs: int = 16,
               sleep_ms: float = 25.0,
               modes=("batch", "per-slot")) -> List[Dict]:
    rows = []
    for n in worker_counts:
        for mode in modes:
            if mode == "batch" and n == 1:
                continue  # batching needs >1 slot to amortise anything
            with RestGateway(IDDS(executor=DistributedWFM(
                    lease_ttl=10.0))) as gw:
                client = IDDSClient(gw.url)
                with WorkerPool(gw.url, concurrency=n,
                                poll_interval=0.01,
                                batch=(mode == "batch"),
                                worker_id=f"bench{n}"):
                    t0 = time.perf_counter()
                    rid = client.submit_workflow(
                        _workflow(jobs, sleep_ms))
                    client.wait(rid, timeout=300, interval=0.01)
                    wall = time.perf_counter() - t0
            rows.append({
                "workers": n,
                "mode": mode,
                "jobs": jobs,
                "sleep_ms": sleep_ms,
                "wall_s": round(wall, 3),
                "jobs_per_s": round(jobs / wall, 2),
            })
    return rows


def heartbeat_overhead(renewals: int = 100) -> Dict:
    """Round-trip cost of one lease renewal over HTTP — the tax a
    worker pays every ttl/3 seconds while executing."""
    with RestGateway(IDDS(executor=DistributedWFM(
            lease_ttl=600.0))) as gw:
        sched = gw.idds.scheduler
        sched.enqueue(Processing(proc_id="hb-probe", work_id="w",
                                 payload="noop", params={}))
        client = IDDSClient(gw.url)
        job = client.lease_job("hb-bench")
        assert job is not None
        samples = []
        for _ in range(renewals):
            t0 = time.perf_counter()
            client.heartbeat_job(job["job_id"], "hb-bench")
            samples.append((time.perf_counter() - t0) * 1e3)
        client.complete_job(job["job_id"], "hb-bench", result={})
    samples.sort()
    return {
        "workers": "heartbeat",
        "jobs": renewals,
        "hb_p50_ms": round(statistics.median(samples), 3),
        "hb_p95_ms": round(samples[int(len(samples) * 0.95) - 1], 3),
    }


def run(worker_counts=(1, 2, 4, 8, 16), jobs: int = 64,
        sleep_ms: float = 25.0, renewals: int = 100) -> List[Dict]:
    rows = throughput(worker_counts, jobs, sleep_ms)
    rows.append(heartbeat_overhead(renewals))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", action="store_true",
                    dest="smoke", help="fewer jobs/renewals (CI)")
    args = ap.parse_args(argv)
    rows = (run(worker_counts=(1, 2, 4), jobs=12, sleep_ms=20.0,
                renewals=40) if args.smoke else run())
    print(",".join(KEYS))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in KEYS))


if __name__ == "__main__":
    main()
