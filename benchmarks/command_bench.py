"""Benchmark: lifecycle-command round-trip latency.

Measures the steering plane an operator leans on in an incident: the
wall time from ``POST /v1/requests/<id>/commands`` to the Commander
journaling the command ``done`` (suspend->resume pairs against live
requests over the wire).  Reports p50/p95 round-trip latency and
commands/sec per client count, in the same keys-header-then-CSV-rows
shape as the other benchmarks driven by benchmarks/run.py.

    PYTHONPATH=src python -m benchmarks.command_bench [--quick]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

from benchmarks.rest_bench import _percentile
from repro.core.client import IDDSClient
from repro.core.idds import IDDS
from repro.core.requests import Request
from repro.core.rest import RestGateway
from repro.core.spec import WorkflowSpec

KEYS = ["requests", "commands", "wall_s", "cmd_per_s",
        "rt_p50_ms", "rt_p95_ms"]


def _request_json() -> str:
    spec = WorkflowSpec("cmd-bench")
    # a long-sleeping work keeps the request steerable for the whole run
    spec.work("s", payload="sleep_ms", defaults={"ms": 2000}, start={})
    return Request(workflow=spec.build()).to_json()


def run_one(n_requests: int, *, pairs_per_request: int = 4) -> Dict:
    """suspend/resume round trips against ``n_requests`` live requests."""
    with RestGateway(IDDS(sync=False, max_workers=4)) as gw:
        client = IDDSClient(gw.url)
        rids = [client.submit(_request_json()) for _ in range(n_requests)]
        lats: List[float] = []
        t0 = time.perf_counter()
        for rid in rids:
            for _ in range(pairs_per_request):
                for action in ("suspend", "resume"):
                    t1 = time.perf_counter()
                    cmd = client.command(rid, action, wait=True)
                    lats.append(time.perf_counter() - t1)
                    assert cmd["status"] == "done", cmd
        wall = time.perf_counter() - t0
        for rid in rids:  # leave no live payloads behind
            client.abort(rid, wait=True)
        return {
            "requests": n_requests,
            "commands": len(lats),
            "wall_s": round(wall, 3),
            "cmd_per_s": round(len(lats) / wall, 1),
            "rt_p50_ms": round(_percentile(lats, 0.50) * 1e3, 2),
            "rt_p95_ms": round(_percentile(lats, 0.95) * 1e3, 2),
        }


def run(request_counts=(1, 4), *, pairs_per_request: int = 4) -> List[Dict]:
    return [run_one(n, pairs_per_request=pairs_per_request)
            for n in request_counts]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    rows = run((1,) if args.quick else (1, 4),
               pairs_per_request=2 if args.quick else 4)
    print(",".join(KEYS))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in KEYS))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
