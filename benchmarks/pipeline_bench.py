"""Benchmark: delivery pipeline (granularity + straggler hedging).

(1) granularity sweep: time-to-first-batch and total delivery time as a
    function of shard count for a fixed corpus (finer shards -> earlier
    first batch; the paper's 'optimal granularity' trade-off);
(2) hedging: delivery tail with and without duplicate requests for
    straggling tape reads.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.carousel.delivery import DeliveryIterator
from repro.carousel.stager import Stager
from repro.carousel.storage import DiskCache
from repro.carousel.transform import make_packing_transform
from repro.data.synthetic import build_cold_store

SEQ = 64
TOTAL_DOCS = 256


def _deliver(n_shards: int, *, latency: float = 0.01, hedge: bool = True,
             straggler: float = 0.0) -> Dict:
    cold = build_cold_store(
        n_shards=n_shards, docs_per_shard=TOTAL_DOCS // n_shards,
        vocab_size=512, mean_doc_len=SEQ, drives=4, mount_latency=latency)
    if straggler:
        cold.straggler_frac = straggler    # per-read tail latency
        cold.straggler_mult = 25.0
    cache = DiskCache(1 << 30)
    names = [f.name for f in cold.files()]
    st = Stager(cold, cache, workers=4, hedge_factor=2.5,
                hedge_min_samples=6, transform=make_packing_transform(SEQ))
    t0 = time.time()
    st.submit_all(names)
    it = DeliveryIterator(st, cache, names, batch_rows=4)
    n_batches = 0
    first = None
    if not hedge:
        st.hedge_factor = float("inf")
    for b in it:
        if first is None:
            first = time.time() - t0
        n_batches += 1
    total = time.time() - t0
    st.shutdown()
    return {"n_shards": n_shards, "ttfb_ms": round(1e3 * (first or 0), 1),
            "total_ms": round(1e3 * total, 1), "batches": n_batches,
            "hedges": st.hedges_issued}


def run() -> List[Dict]:
    rows = []
    for n in (2, 8, 32):
        r = _deliver(n)
        r["sweep"] = "granularity"
        rows.append(r)
    for hedge in (False, True):
        r = _deliver(16, straggler=0.25, hedge=hedge)
        r["sweep"] = f"straggler hedge={hedge}"
        rows.append(r)
    return rows


def main():
    keys = ["sweep", "n_shards", "ttfb_ms", "total_ms", "batches", "hedges"]
    print(",".join(keys))
    for r in run():
        print(",".join(str(r.get(k, "")) for k in keys))


if __name__ == "__main__":
    main()
