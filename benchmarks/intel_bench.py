"""Benchmark: intelligence-plane dispatch vs legacy FIFO dispatch.

Simulates a skewed tape-carousel workload against the real
:class:`repro.core.scheduler.JobScheduler` under an injected clock:
jobs are grouped into datasets with Zipf-skewed popularity, every
worker keeps a small LRU content cache (the pilot-side data cache),
and a job's service time is dominated by how many of its input files
the executing worker must pull cold.  The event loop advances
simulated time only — no sleeping — so both arms replay the identical
workload deterministically:

* ``intel=off``: the legacy FIFO-within-priority dispatch.  Datasets
  interleave arbitrarily across workers, so almost every job pays the
  cold-read penalty.
* ``intel=on``: workers report their cache manifest with each lease
  and the scheduler scores candidates by input affinity, keeping a
  dataset's jobs on the worker that already holds its files.

Reported per arm: makespan, p50/p99 time-to-delivered (enqueue ->
completion), the fraction of file reads served cold, and the
scheduler's affinity hit-rate.  The intel arm must strictly beat the
FIFO arm on p99 TTD (gated by scripts/bench_diff.py).

    PYTHONPATH=src python -m benchmarks.intel_bench [--smoke]
"""
from __future__ import annotations

import argparse
import heapq
import random
from collections import OrderedDict
from typing import Dict, List

from repro.core.intel import IntelPlane
from repro.core.scheduler import JobScheduler
from repro.core.workflow import Processing

KEYS = ["arm", "jobs", "workers", "datasets", "makespan_s",
        "p50_ttd_s", "p99_ttd_s", "cold_fraction", "affinity_hit_rate"]


def _percentile(sorted_vals: List[float], q: float) -> float:
    n = len(sorted_vals)
    return sorted_vals[min(n - 1, int(q / 100.0 * n))]


def simulate(*, jobs: int, workers: int, datasets: int = 12,
             files_per_dataset: int = 8, cache_capacity: int = 8,
             base_s: float = 0.02, miss_penalty_s: float = 0.05,
             intel_on: bool = False, scan_width: int = 16,
             seed: int = 7) -> Dict:
    """Replay one arm of the workload; returns a result row."""
    now = [0.0]
    sched = JobScheduler(default_ttl=1e9, max_ttl=1e9, worker_ttl=1e9,
                         clock=lambda: now[0])
    if intel_on:
        sched.enable_intel(IntelPlane(scan_width=scan_width))

    rng = random.Random(seed)
    files = {d: [f"ds{d:02d}/shard{i:02d}" for i in range(files_per_dataset)]
             for d in range(datasets)}
    # Zipf-skewed dataset popularity: a few hot datasets dominate, the
    # long tail shows up rarely — the carousel's access pattern
    weights = [1.0 / (k + 1) for k in range(datasets)]
    assignment = rng.choices(range(datasets), weights=weights, k=jobs)
    for j, d in enumerate(assignment):
        sched.enqueue(Processing(proc_id=f"job-{j:05d}", work_id=f"ds{d}",
                                 payload="noop", params={"queue": "tape"},
                                 input_files=list(files[d])))

    caches: Dict[int, "OrderedDict[str, None]"] = {
        w: OrderedDict() for w in range(workers)}
    in_flight: Dict[int, str] = {}  # worker -> job_id finishing now
    events = [(0.0, w) for w in range(workers)]
    heapq.heapify(events)
    ttds: List[float] = []
    cold = total = 0

    while events:
        t, w = heapq.heappop(events)
        now[0] = t
        done = in_flight.pop(w, None)
        if done is not None:
            sched.complete(done, f"w{w}", result={})
        manifest = list(caches[w]) if intel_on else None
        job = sched.lease(f"w{w}", manifest=manifest)
        if job is None:
            continue  # queue drained; this worker retires
        cache = caches[w]
        misses = sum(1 for f in job["input_files"] if f not in cache)
        cold += misses
        total += len(job["input_files"])
        for f in job["input_files"]:
            cache.pop(f, None)
            cache[f] = None
        while len(cache) > cache_capacity:
            cache.popitem(last=False)
        finish = t + base_s + miss_penalty_s * misses
        ttds.append(finish)  # every job is enqueued at t=0
        in_flight[w] = job["job_id"]
        heapq.heappush(events, (finish, w))

    ttds.sort()
    intel = sched.intel
    hit_rate = intel.affinity_hit_rate() if intel is not None else None
    return {
        "arm": "on" if intel_on else "off",
        "jobs": jobs,
        "workers": workers,
        "datasets": datasets,
        "makespan_s": round(ttds[-1], 4),
        "p50_ttd_s": round(_percentile(ttds, 50), 4),
        "p99_ttd_s": round(_percentile(ttds, 99), 4),
        "cold_fraction": round(cold / total, 4) if total else 0.0,
        "affinity_hit_rate": (round(hit_rate, 4)
                              if hit_rate is not None else ""),
    }


def run(jobs: int = 1200, workers: int = 8, **kw) -> List[Dict]:
    """Both arms over the identical seeded workload."""
    return [simulate(jobs=jobs, workers=workers, intel_on=False, **kw),
            simulate(jobs=jobs, workers=workers, intel_on=True, **kw)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", action="store_true",
                    dest="smoke", help="fewer jobs (CI)")
    args = ap.parse_args(argv)
    rows = run(jobs=240, workers=4) if args.smoke else run()
    print(",".join(KEYS))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in KEYS))


if __name__ == "__main__":
    main()
