"""Benchmark: persistence overhead + recovery speed (paper §2 catalogs).

Compares sustained head-service throughput with the in-memory store
against the SQLite-backed store (WAL): submissions/sec into a live
service, end-to-end workflows/sec through the full daemon machinery,
and — for SQLite — how fast a fresh head service can ``recover()`` the
whole catalog after a simulated crash.  This is the price of durability
the ROADMAP's horizontally-scalable head service pays per request.

Also measures the content-journal write path one row per transaction
versus batched (``save_contents`` with many rows = one transaction via
``save_many``): the ``bulk_speedup`` row is the acceptance number for
the bulk hot-path work — SQLite bulk should be >=10x one-row.

    PYTHONPATH=src python -m benchmarks.store_bench [--smoke]
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time
from typing import Dict, List

from repro.core.idds import IDDS
from repro.core.requests import Request
from repro.core.spec import WorkflowSpec
from repro.core.store import InMemoryStore, SqliteStore
from repro.core.workflow import FileRef

KEYS = ["store", "submissions", "submit_wall_s", "submit_per_s",
        "pump_wall_s", "e2e_per_s", "recover_s", "recovered_works",
        "write_rows", "write_wall_s", "rows_per_s", "bulk_speedup"]


def _make_request_json() -> str:
    spec = WorkflowSpec("store-bench")
    spec.work("n", payload="noop", start={})
    return Request(workflow=spec.build()).to_json()


def run_one(kind: str, n: int, workdir: str) -> Dict:
    path = os.path.join(workdir, f"bench-{kind}.db")
    store = SqliteStore(path) if kind == "sqlite" else InMemoryStore()
    idds = IDDS(store=store)
    payloads = [_make_request_json() for _ in range(n)]  # not timed

    t0 = time.perf_counter()
    rids = [idds.submit(p) for p in payloads]
    t1 = time.perf_counter()
    idds.pump()
    t2 = time.perf_counter()
    finished = sum(idds.request_status(r)["status"] == "finished"
                   for r in rids)
    assert finished == n, f"{finished}/{n} finished"

    recover_s = 0.0
    recovered_works = 0
    if kind == "sqlite":
        idds.close()
        fresh = IDDS(store=SqliteStore(path))
        t3 = time.perf_counter()
        counts = fresh.recover()
        recover_s = time.perf_counter() - t3
        recovered_works = counts["works"]
        fresh.close()
    else:
        idds.close()

    sub_wall, pump_wall = t1 - t0, t2 - t1
    return {
        "store": kind,
        "submissions": n,
        "submit_wall_s": round(sub_wall, 3),
        "submit_per_s": round(n / sub_wall),
        "pump_wall_s": round(pump_wall, 3),
        "e2e_per_s": round(n / (sub_wall + pump_wall)),
        "recover_s": round(recover_s, 3),
        "recovered_works": recovered_works,
    }


def content_write_rates(n_rows: int, batch: int,
                        workdir: str) -> List[Dict]:
    """Content journal rows/s, one row per transaction vs batched
    (``save_contents`` with ``batch`` rows = one ``save_many`` commit).
    The ``bulk_speedup`` rows are the fsync-amortisation factor."""
    files = [FileRef(f"f{i}", size=i, available=True).to_dict()
             for i in range(n_rows)]
    rows: List[Dict] = []
    for kind in ("memory", "sqlite"):
        rates: Dict[str, float] = {}
        for mode in ("one-row", "bulk"):
            path = os.path.join(workdir, f"wr-{kind}-{mode}.db")
            store = (SqliteStore(path) if kind == "sqlite"
                     else InMemoryStore())
            t0 = time.perf_counter()
            if mode == "bulk":
                for i in range(0, n_rows, batch):
                    store.save_contents("bench", files[i:i + batch])
            else:
                for f in files:
                    store.save_contents("bench", [f])
            wall = time.perf_counter() - t0
            store.close()
            rates[mode] = n_rows / wall
            rows.append({"store": f"{kind}-{mode}",
                         "write_rows": n_rows,
                         "write_wall_s": round(wall, 3),
                         "rows_per_s": round(n_rows / wall, 1)})
        rows.append({"store": f"{kind}-bulk_speedup",
                     "bulk_speedup": round(rates["bulk"]
                                           / rates["one-row"], 2)})
    return rows


def run(n: int = 300, write_rows: int = 2000,
        write_batch: int = 256) -> List[Dict]:
    rows = []
    with tempfile.TemporaryDirectory(prefix="idds-store-bench-") as d:
        for kind in ("memory", "sqlite"):
            rows.append(run_one(kind, n, d))
        mem, sql = rows
        rows.append({
            "store": "ratio(memory/sqlite)",
            "submit_per_s": round(mem["submit_per_s"]
                                  / max(sql["submit_per_s"], 1), 2),
            "e2e_per_s": round(mem["e2e_per_s"]
                               / max(sql["e2e_per_s"], 1), 2),
        })
        rows.extend(content_write_rates(write_rows, write_batch, d))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", action="store_true",
                    dest="smoke", help="fewer submissions (CI)")
    ap.add_argument("-n", type=int, default=None,
                    help="submissions per store backend")
    args = ap.parse_args(argv)
    n = args.n if args.n is not None else (50 if args.smoke else 300)
    rows = run(n, write_rows=500 if args.smoke else 2000)
    print(",".join(KEYS))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in KEYS))


if __name__ == "__main__":
    main()
