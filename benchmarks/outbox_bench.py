"""Benchmark: the push-delivery plane (transactional outbox).

(1) notify latency — how long after a file becomes available does the
    consumer observe its delivery, per channel:
      poll-1s    the pre-outbox baseline: a client polling
                 ``GET .../deliveries`` once per second (p50 sits at
                 half the poll interval by construction);
      long-poll  ``GET .../deliveries?wait_s=`` parked on the head's
                 delivery condition — wakes the moment the Conductor
                 journals the delivery;
      webhook    the Publisher POSTs the outbox batch to the
                 subscriber's endpoint;
(2) fan-out throughput — one available-file event against N webhook/bus
    subscribers: the Publisher's batched path (one journal commit per
    drained batch) vs a simulated per-request path (one insert + one
    status commit per message, the naive outbox implementation).

    PYTHONPATH=src python -m benchmarks.outbox_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import tempfile
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List

from repro.core import payloads as reg
from repro.core.client import IDDSClient
from repro.core.idds import IDDS
from repro.core.rest import RestGateway
from repro.core.spec import WorkflowSpec
from repro.core.store import SqliteStore
from repro.core.workflow import FileRef

KEYS = ["arm", "events", "p50_ms", "p95_ms", "subscribers",
        "deliveries", "wall_ms", "deliveries_per_s", "speedup"]

reg.register_payload("outbox_bench_echo",
                     lambda params, inputs: {"inputs": list(inputs)})


def _announce(idds: IDDS, tag: str) -> None:
    """Make one file available in a fresh collection and pump until the
    Conductor has journaled the deliveries (and the Publisher fanned
    them out)."""
    idds.ctx.ddm.register_collection(
        f"tape.{tag}", [FileRef(f"{tag}-f0", size=1, available=True)])
    spec = WorkflowSpec(f"bench-{tag}")
    spec.work("proc", payload="outbox_bench_echo",
              input_collection=f"tape.{tag}",
              output_collection=f"out.{tag}", granularity="fine",
              start={})
    idds.submit_workflow(spec.build())
    idds.pump()


class _StampReceiver:
    """Webhook endpoint that records the monotonic arrival time of each
    delivery batch."""

    def __init__(self):
        self.stamps: List[float] = []
        recv = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, fmt, *args):  # noqa: A003
                pass

            def do_POST(self):  # noqa: N802
                t = time.monotonic()
                length = int(self.headers.get("Content-Length", 0) or 0)
                self.rfile.read(length)
                recv.stamps.append(t)
                self.send_response(200)
                self.send_header("Content-Length", "2")
                self.end_headers()
                self.wfile.write(b"{}")

        self.httpd = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
        threading.Thread(target=self.httpd.serve_forever,
                         daemon=True).start()

    @property
    def url(self) -> str:
        return f"http://127.0.0.1:{self.httpd.server_address[1]}/hook"

    def close(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()


def _percentiles(samples_s: List[float]) -> Dict:
    ms = sorted(1e3 * s for s in samples_s)
    return {
        "events": len(ms),
        "p50_ms": round(statistics.median(ms), 2),
        "p95_ms": round(ms[min(len(ms) - 1, int(0.95 * len(ms)))], 2),
    }


def _latency_poll(gw: RestGateway, events: int,
                  poll_interval: float) -> Dict:
    client = IDDSClient(gw.url)
    samples = []
    for i in range(events):
        sub = client.subscribe(f"poll-{i}", [f"out.poll{i}"])
        done = threading.Event()
        out = {}

        def watch(sub_id=sub["sub_id"]):
            # the baseline consumer: wake once per interval and ask
            while not done.is_set():
                res = client.list_deliveries(sub_id)
                if res["total"]:
                    out["t"] = time.monotonic()
                    return
                done.wait(poll_interval)

        t = threading.Thread(target=watch, daemon=True)
        t.start()
        # stagger the announcement phase across the poll interval so
        # the sample median lands at the analytic interval/2
        time.sleep(0.02 + poll_interval * (i + 0.5) / events)
        t0 = time.monotonic()
        _announce(gw.idds, f"poll{i}")
        t.join(timeout=10)
        done.set()
        samples.append(out["t"] - t0)
    return {"arm": "poll-1s", **_percentiles(samples)}


def _latency_long_poll(gw: RestGateway, events: int) -> Dict:
    client = IDDSClient(gw.url)
    samples = []
    for i in range(events):
        sub = client.subscribe(f"lp-{i}", [f"out.lp{i}"])
        out = {}

        def watch(sub_id=sub["sub_id"]):
            res = client.wait_deliveries(sub_id, wait_s=10.0)
            if res["total"]:
                out["t"] = time.monotonic()

        t = threading.Thread(target=watch, daemon=True)
        t.start()
        time.sleep(0.05)  # the handler must be parked before t0
        t0 = time.monotonic()
        _announce(gw.idds, f"lp{i}")
        t.join(timeout=12)
        samples.append(out["t"] - t0)
    return {"arm": "long-poll", **_percentiles(samples)}


def _latency_webhook(idds: IDDS, events: int) -> Dict:
    recv = _StampReceiver()
    try:
        samples = []
        for i in range(events):
            idds.subscribe(f"wh-{i}", [f"out.wh{i}"],
                           push_url=recv.url)
            n0 = len(recv.stamps)
            t0 = time.monotonic()
            _announce(idds, f"wh{i}")
            deadline = time.monotonic() + 10.0
            while len(recv.stamps) <= n0 \
                    and time.monotonic() < deadline:
                time.sleep(0.001)
            samples.append(recv.stamps[n0] - t0)
        return {"arm": "webhook", **_percentiles(samples)}
    finally:
        recv.close()


def _fanout(path: str, subscribers: int, batch_size: int,
            arm: str, push_url: str) -> Dict:
    """One available file against N webhook subscribers; the timed
    window is the Publisher's drain of the journaled backlog.
    ``batch_size`` selects the arm: the real batched path groups the
    batch into one POST per endpoint and one O(batch) status commit
    per round; batch_size=1 is the per-request implementation — claim
    check, query, one POST, and one single-row commit per message."""
    from repro.core.daemons import Publisher

    idds = IDDS(store=SqliteStore(path))
    pub = next(d for d in idds.daemons if isinstance(d, Publisher))
    pub.batch_size = batch_size
    pub.__dict__["process_once"] = lambda: 0  # park the fan-out
    for i in range(subscribers):
        idds.subscribe(f"fan-{i}", ["out.fan"], push_url=push_url)
    _announce(idds, "fan")  # journals N outbox rows, all still `new`
    backlog = idds.store.count_messages(statuses=("new",))
    assert backlog == subscribers, (backlog, subscribers)
    del pub.__dict__["process_once"]
    t0 = time.monotonic()
    while pub.process_once():
        pass
    wall = time.monotonic() - t0
    delivered = idds.store.count_messages(statuses=("delivered",))
    idds.close()
    assert delivered == subscribers, (delivered, subscribers)
    return {"arm": arm, "subscribers": subscribers,
            "deliveries": delivered, "wall_ms": round(1e3 * wall, 1),
            "deliveries_per_s": round(delivered / wall, 1)}


def run(*, events: int = 9, subscribers: int = 1000,
        poll_interval: float = 1.0) -> List[Dict]:
    out = []

    # --- notify latency, per channel ------------------------------
    idds = IDDS()
    gw = RestGateway(idds)
    gw.start()
    try:
        out.append(_latency_poll(gw, events, poll_interval))
        out.append(_latency_long_poll(gw, events))
        out.append(_latency_webhook(idds, events))
    finally:
        gw.stop()
    poll_p50 = out[0]["p50_ms"]
    for row in out[1:]:
        row["speedup"] = round(poll_p50 / max(row["p50_ms"], 1e-3), 1)

    # --- fan-out throughput at N subscribers ----------------------
    d = tempfile.mkdtemp(prefix="idds_outbox_")
    recv = _StampReceiver()
    try:
        batched = _fanout(os.path.join(d, "batched.db"), subscribers,
                          256, "fanout-batched", recv.url)
        per_req = _fanout(os.path.join(d, "per_request.db"),
                          subscribers, 1, "fanout-per-request",
                          recv.url)
    finally:
        recv.close()
    batched["speedup"] = round(batched["deliveries_per_s"]
                               / max(per_req["deliveries_per_s"], 1e-3),
                               1)
    out.extend([batched, per_req])
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI")
    ap.add_argument("--json-out", default=None, metavar="PATH")
    args = ap.parse_args(argv)
    rows = (run(events=3, subscribers=100) if args.smoke else run())
    print(",".join(KEYS))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in KEYS))
    by_arm = {r["arm"]: r for r in rows}
    lp = by_arm["poll-1s"]["p50_ms"] / by_arm["long-poll"]["p50_ms"]
    wh = by_arm["poll-1s"]["p50_ms"] / by_arm["webhook"]["p50_ms"]
    fan = by_arm["fanout-batched"]["speedup"]
    print(f"\npush notify p50: long-poll {lp:.0f}x lower, webhook "
          f"{wh:.0f}x lower than poll-at-{1.0:.0f}s; batched fan-out "
          f"{fan:.1f}x the per-request deliveries/sec at "
          f"{by_arm['fanout-batched']['subscribers']} subscribers")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=2, sort_keys=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
