"""Run every benchmark; one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick | --smoke]
                                            [--json-out results.json]

Sections:
  carousel   Fig. 4/5  fine vs coarse granularity (attempts/disk/makespan)
  hpo        Fig. 6    optimizer quality + async evaluation speedup
  dag        §3.3.1    Rubin-scale DAG scheduling throughput
  pipeline   §1        delivery granularity + straggler hedging
  delivery   §3.1      content delivery plane: time-to-first-delivery
                       fine vs coarse + content-journal rows/s
  store      §2        persistence overhead: in-memory vs SQLite catalogs
  obs        §2        telemetry overhead: metrics/tracing on vs off
                       (the <=5% always-on gate)
  train      §3.1      carousel-fed training micro-run (loss goes down)
  rest       §2        REST gateway submission throughput + poll latency
  outbox     §2        push-delivery plane: notify latency per channel
                       (poll vs long-poll vs webhook) + batched vs
                       per-request fan-out at N subscribers
  cluster    §2        multi-head horizontal scaling: aggregate
                       submissions/sec at 1 vs 2 heads on one catalog
  command    §2        steering plane: lifecycle-command round-trip
                       latency (suspend/resume over the wire)
  worker     §2        distributed execution plane: jobs/sec vs worker
                       count + lease-renewal overhead
  intel      §3        intelligence plane: locality-aware dispatch vs
                       legacy FIFO on a skewed tape workload (makespan,
                       p99 time-to-delivered, affinity hit-rate)
  roofline   —         per-cell roofline terms from the dry-run sweep

Modes: full (default) the paper-scale sweeps; ``--quick`` smaller
sweeps; ``--smoke`` the minimal CI pass — service-layer sections only
(train needs a jax install and the roofline needs a dry-run sweep, so
both are skipped).  ``--json-out`` writes every section's rows to one
JSON file (the CI bench-smoke artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _section(name):
    print(f"\n===== {name} =====", flush=True)


def _print_rows(keys, rows):
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal CI pass: tiny sweeps, service-layer "
                         "sections only (no jax required)")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="write all section results to a JSON file")
    args = ap.parse_args(argv)
    smoke = args.smoke
    quick = args.quick or smoke

    t0 = time.time()
    results = {}

    _section("carousel (paper Figs. 4-5)")
    from benchmarks import carousel_sim
    if smoke:
        carousel_sim.CAMPAIGNS = {
            "smoke-200f": dict(n_files=200, disk_capacity=1.2e12)}
    elif quick:
        carousel_sim.CAMPAIGNS = {
            "small-500f": dict(n_files=500, disk_capacity=1.2e12)}
    results["carousel"] = carousel_sim.run()
    carousel_keys = ["campaign", "mode", "job_attempts", "attempts_per_job",
                     "failed_attempts", "peak_disk_TB", "disk_TB_hours",
                     "ttfp_h", "makespan_h"]
    _print_rows(carousel_keys, results["carousel"])

    _section("hpo (paper Fig. 6)")
    from benchmarks import hpo_bench
    budget = 16 if smoke else (24 if quick else 64)
    results["hpo"] = hpo_bench.quality(budget=budget)
    _print_rows(["objective", "optimizer", "budget", "best_mean",
                 "best_min"], results["hpo"])
    if not quick:
        results["hpo_async"] = hpo_bench.async_speedup()
        _print_rows(["workers", "budget", "wall_s", "trials_per_s"],
                    results["hpo_async"])

    _section("dag (paper §3.3.1, Rubin)")
    from benchmarks import dag_bench
    sizes = ((1_000,) if smoke else
             (1_000, 10_000) if quick else (1_000, 10_000, 100_000))
    results["dag"] = dag_bench.run(sizes)
    _print_rows(["jobs", "wall_s", "jobs_per_s", "released", "pump_rounds",
                 "us_per_job"], results["dag"])

    _section("pipeline (delivery granularity + hedging)")
    from benchmarks import pipeline_bench
    results["pipeline"] = pipeline_bench.run()
    _print_rows(["sweep", "n_shards", "ttfb_ms", "total_ms", "batches",
                 "hedges"], results["pipeline"])

    _section("delivery (content delivery plane: fine vs coarse TTFD)")
    from benchmarks import delivery_bench
    results["delivery"] = delivery_bench.run(
        n_shards=6 if smoke else 12,
        latency=0.02 if quick else 0.01,
        n_contents=300 if smoke else 1000 if quick else 2000)
    _print_rows(delivery_bench.KEYS, results["delivery"])

    _section("store (paper §2, persistence overhead)")
    from benchmarks import store_bench
    results["store"] = store_bench.run(
        n=50 if smoke else 100 if quick else 300,
        write_rows=500 if smoke else 1000 if quick else 2000)
    _print_rows(store_bench.KEYS, results["store"])

    _section("obs (telemetry overhead: metrics/tracing on vs off)")
    from benchmarks import obs_bench
    results["obs"] = obs_bench.run(
        n=30 if smoke else 50,
        write_rows=500 if smoke else 1000 if quick else 2000,
        pairs=12 if smoke else 16 if quick else 40,
        instrument_ops=50_000 if quick else 200_000)
    _print_rows(obs_bench.KEYS, results["obs"])

    if smoke:
        _section("train (skipped in --smoke: needs jax)")
        results["train"] = {"skipped": "smoke mode (jax compile cost)"}
    else:
        _section("train (carousel-fed smoke training)")
        from repro.launch.train import run_training
        res = run_training("yi-6b", smoke=True, steps=20, seq_len=32,
                           global_batch=4, carousel=True)
        results["train"] = {
            "arch": "yi-6b", "steps": res["steps"],
            "first_loss": round(res["first_loss"], 3),
            "last_loss": round(res["last_loss"], 3),
            "ttfb_s": round(res["time_to_first_batch_s"], 2),
            "wall_s": round(res["wall_s"], 1)}
        _print_rows(["arch", "steps", "first_loss", "last_loss", "ttfb_s",
                     "wall_s"], [results["train"]])

    _section("rest (paper §2, gateway throughput)")
    from benchmarks import rest_bench
    results["rest"] = rest_bench.run(
        client_counts=(1, 4) if smoke else (1, 4, 8),
        per_client=5 if smoke else 10 if quick else 25)
    _print_rows(rest_bench.KEYS, results["rest"])

    _section("outbox (push-delivery plane: notify latency + fan-out)")
    from benchmarks import outbox_bench
    results["outbox"] = outbox_bench.run(
        events=3 if smoke else 5 if quick else 9,
        subscribers=100 if smoke else 300 if quick else 1000)
    _print_rows(outbox_bench.KEYS, results["outbox"])

    _section("cluster (multi-head: 1 vs 2 heads, one catalog)")
    from benchmarks import cluster_bench
    results["cluster"] = cluster_bench.run(
        head_counts=(1, 2),
        clients_per_head=2 if smoke else 4,
        per_client=5 if smoke else 10 if quick else 25)
    _print_rows(cluster_bench.KEYS, results["cluster"])

    _section("command (steering plane round-trip latency)")
    from benchmarks import command_bench
    results["command"] = command_bench.run(
        (1,) if smoke else (1, 4),
        pairs_per_request=2 if quick else 4)
    _print_rows(command_bench.KEYS, results["command"])

    _section("worker (distributed execution plane)")
    from benchmarks import worker_bench
    results["worker"] = worker_bench.run(
        worker_counts=(1, 2, 4) if smoke else
        (1, 2, 4, 8) if quick else (1, 2, 4, 8, 16),
        jobs=12 if smoke else 24 if quick else 64,
        sleep_ms=20.0 if quick else 25.0,
        renewals=40 if quick else 100)
    _print_rows(worker_bench.KEYS, results["worker"])

    _section("intel (intelligence plane: affinity dispatch vs FIFO)")
    from benchmarks import intel_bench
    results["intel"] = intel_bench.run(
        jobs=240 if smoke else 600 if quick else 1200,
        workers=4 if smoke else 8)
    _print_rows(intel_bench.KEYS, results["intel"])

    if smoke:
        _section("roofline (skipped in --smoke: needs a dry-run sweep)")
        results["roofline"] = {"skipped": "smoke mode (no dryrun sweep)"}
    else:
        _section("roofline (dry-run sweep)")
        from benchmarks import roofline
        roofline.main()

    wall = round(time.time() - t0, 1)
    skipped = sorted(name for name, res in results.items()
                     if isinstance(res, dict) and "skipped" in res)
    if skipped:
        print(f"\nWARNING: skipped benchmarks: {', '.join(skipped)} "
              f"(rerun without --smoke for full coverage)", flush=True)
    print(f"\nall benchmarks done in {wall}s")

    if args.json_out:
        mode = "smoke" if smoke else "quick" if quick else "full"
        with open(args.json_out, "w") as f:
            json.dump({"mode": mode, "wall_s": wall,
                       "git_rev": _git_rev(),
                       "generated_at": _utc_now(),
                       "sections": results}, f, indent=2, sort_keys=True)
        print(f"results written to {args.json_out}")
    return 0


def _git_rev() -> str:
    """The commit the numbers were measured at (provenance for the
    committed BENCH_*.json artifacts and the CI bench artifact)."""
    import subprocess
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))))
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:  # noqa: BLE001 — provenance is best-effort
        pass
    return "unknown"


def _utc_now() -> str:
    import datetime
    return datetime.datetime.now(datetime.timezone.utc).isoformat(
        timespec="seconds")


if __name__ == "__main__":
    sys.exit(main())
