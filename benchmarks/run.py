"""Run every benchmark; one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Sections:
  carousel   Fig. 4/5  fine vs coarse granularity (attempts/disk/makespan)
  hpo        Fig. 6    optimizer quality + async evaluation speedup
  dag        §3.3.1    Rubin-scale DAG scheduling throughput
  pipeline   §1        delivery granularity + straggler hedging
  train      §3.1      carousel-fed training micro-run (loss goes down)
  rest       §2        REST gateway submission throughput + poll latency
  roofline   —         per-cell roofline terms from the dry-run sweep
"""
from __future__ import annotations

import argparse
import sys
import time


def _section(name):
    print(f"\n===== {name} =====", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweeps (CI)")
    args = ap.parse_args(argv)

    t0 = time.time()

    _section("carousel (paper Figs. 4-5)")
    from benchmarks import carousel_sim
    if args.quick:
        carousel_sim.CAMPAIGNS = {
            "small-500f": dict(n_files=500, disk_capacity=1.2e12)}
    carousel_sim.main()

    _section("hpo (paper Fig. 6)")
    from benchmarks import hpo_bench
    if args.quick:
        print("objective,optimizer,budget,best_mean,best_min")
        for r in hpo_bench.quality(budget=24):
            print(f"{r['objective']},{r['optimizer']},{r['budget']},"
                  f"{r['best_mean']:.4f},{r['best_min']:.4f}")
    else:
        hpo_bench.main()

    _section("dag (paper §3.3.1, Rubin)")
    from benchmarks import dag_bench
    sizes = (1_000, 10_000) if args.quick else (1_000, 10_000, 100_000)
    keys = ["jobs", "wall_s", "jobs_per_s", "released", "pump_rounds",
            "us_per_job"]
    print(",".join(keys))
    for r in dag_bench.run(sizes):
        print(",".join(str(r[k]) for k in keys))

    _section("pipeline (delivery granularity + hedging)")
    from benchmarks import pipeline_bench
    pipeline_bench.main()

    _section("train (carousel-fed smoke training)")
    from repro.launch.train import run_training
    res = run_training("yi-6b", smoke=True, steps=20, seq_len=32,
                       global_batch=4, carousel=True)
    print("arch,steps,first_loss,last_loss,ttfb_s,wall_s")
    print(f"yi-6b,{res['steps']},{res['first_loss']:.3f},"
          f"{res['last_loss']:.3f},{res['time_to_first_batch_s']:.2f},"
          f"{res['wall_s']:.1f}")

    _section("rest (paper §2, gateway throughput)")
    from benchmarks import rest_bench
    rows = rest_bench.run(per_client=10 if args.quick else 25)
    print(",".join(rest_bench.KEYS))
    for r in rows:
        print(",".join(str(r[k]) for k in rest_bench.KEYS))

    _section("roofline (dry-run sweep)")
    from benchmarks import roofline
    roofline.main()

    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
