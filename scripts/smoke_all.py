"""Dev script: run one train step + prefill + decode for every smoke arch,
then the REST gateway quickstart (server + client over localhost HTTP)."""
import os
import subprocess
import sys
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (RunConfig, ShapeConfig, get_smoke_config,
                                list_archs)
from repro.models import registry
from repro.serve import engine
from repro.train.step import init_state, make_train_step

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def smoke_one(arch: str) -> None:
    cfg = get_smoke_config(arch)
    run = RunConfig(total_steps=10, warmup_steps=2, scan_layers=True,
                    ce_block_v=64)
    rng = jax.random.PRNGKey(0)
    state = init_state(rng, cfg, run)

    batch = registry.synth_inputs(jax.random.PRNGKey(1), cfg, SHAPE, "train")
    step = jax.jit(make_train_step(cfg, run))
    state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"

    # prefill + decode
    pre = registry.synth_inputs(jax.random.PRNGKey(2), cfg, SHAPE, "prefill")
    cache = engine.init_cache(cfg, SHAPE.global_batch, 64)
    tok, cache = jax.jit(engine.make_prefill_step(cfg, run))(
        state["params"], pre, cache)
    assert tok.shape == (SHAPE.global_batch, 1)
    dec = jax.jit(engine.make_decode_step(cfg, run))
    tok2, cache = dec(state["params"], tok, cache, jnp.asarray(32, jnp.int32))
    assert tok2.shape == (SHAPE.global_batch, 1)
    assert bool(jnp.all(tok2 >= 0))
    print(f"[ok] {arch}: loss={loss:.4f}")


def _smoke_example(name: str) -> None:
    """Run one examples/ script in a subprocess and require success."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    r = subprocess.run(
        [sys.executable, os.path.join(root, "examples", name)],
        cwd=root, env=env, capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]


def smoke_rest() -> None:
    """End-to-end REST quickstart in a subprocess (own server + client)."""
    _smoke_example("rest_quickstart.py")
    print("[ok] rest quickstart (gateway + client over HTTP)")


def smoke_workers() -> None:
    """Execution-plane e2e: head + 2 worker processes over the wire."""
    _smoke_example("distributed_workers.py")
    print("[ok] distributed workers (head + 2 worker processes)")


def smoke_commands() -> None:
    """Command-plane e2e: submit -> suspend -> resume -> abort over the
    wire with a live worker process."""
    _smoke_example("steer_workflow.py")
    print("[ok] command smoke (suspend/resume/abort with a live worker)")


def smoke_carousel() -> None:
    """Delivery-plane e2e: Data Carousel feeding two worker processes —
    per-file dispatch as shards land, content rows + consumer acks."""
    _smoke_example("carousel_workers.py")
    print("[ok] carousel smoke (carousel -> distributed workers)")


if __name__ == "__main__":
    archs = sys.argv[1:] or list_archs()
    failed = []
    for a in archs:
        try:
            smoke_one(a)
        except Exception:
            failed.append(a)
            print(f"[FAIL] {a}")
            traceback.print_exc()
    try:
        smoke_rest()
    except Exception:
        failed.append("rest")
        print("[FAIL] rest")
        traceback.print_exc()
    try:
        smoke_workers()
    except Exception:
        failed.append("workers")
        print("[FAIL] workers")
        traceback.print_exc()
    try:
        smoke_commands()
    except Exception:
        failed.append("commands")
        print("[FAIL] commands")
        traceback.print_exc()
    try:
        smoke_carousel()
    except Exception:
        failed.append("carousel")
        print("[FAIL] carousel")
        traceback.print_exc()
    sys.exit(1 if failed else 0)
