"""Assemble dryrun_results_optimized.json from the crashed sweep's log
(single-pod cells) + per-arch part files (multi-pod + the one recovered
single-pod cell), and refresh dryrun_results.json (the file benchmarks
read) to the optimized table."""
import glob
import json
import re
import sys

LOG_RE = re.compile(
    r"^\[ok\] (\S+) x (\S+) mesh=(\S+) flops/dev=(\S+) bytes/dev=(\S+) "
    r"coll/dev=(\S+) dom=(\S+) bound=(\S+)ms useful=(\S+) compile=(\S+)s")
SKIP_RE = re.compile(r"^\[skipped\] (\S+) x (\S+) mesh=(\S+) \((.*)\)")

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def cell_from_log(m):
    arch, shape, mesh, flops, byts, coll, dom, bound, useful, comp = m.groups()
    flops, byts, coll = float(flops), float(byts), float(coll)
    chips = 256 if mesh == "16x16" else 512
    t_c, t_m, t_n = flops / PEAK, byts / HBM, coll / ICI
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n}
    d = max(terms, key=terms.get)
    return {
        "arch": arch, "shape": shape, "status": "ok",
        "mesh": mesh, "chips": chips,
        "hlo_flops": flops, "hlo_bytes": byts, "collective_total": coll,
        "compile_s": float(comp), "recovered_from_log": True,
        "roofline": {**terms, "dominant": d,
                     "bound_s": max(t_c, t_m, t_n),
                     "compute_fraction": t_c / max(t_c, t_m, t_n, 1e-30),
                     "useful_flops_ratio": float(useful)},
    }


def main():
    cells = []
    with open("dryrun_sweep2.log") as f:
        for line in f:
            m = LOG_RE.match(line.strip())
            if m:
                cells.append(cell_from_log(m))
                continue
            s = SKIP_RE.match(line.strip())
            if s and s.group(3) == "16x16":
                cells.append({"arch": s.group(1), "shape": s.group(2),
                              "status": "skipped", "reason": s.group(4)})
    for path in sorted(glob.glob("dr_parts/*.json")):
        try:
            cells.extend(json.load(open(path)))
        except Exception as e:
            print("bad part", path, e, file=sys.stderr)
    # dedupe on (arch, shape, chips/mesh)
    seen = {}
    for c in cells:
        key = (c["arch"], c["shape"], c.get("chips", c.get("mesh", "skip")),
               c["status"])
        seen[key] = c
    out = list(seen.values())
    ok = sum(1 for c in out if c["status"] == "ok")
    sk = sum(1 for c in out if c["status"] == "skipped")
    er = sum(1 for c in out if c["status"] == "error")
    json.dump(out, open("dryrun_results_optimized.json", "w"), indent=1)
    json.dump(out, open("dryrun_results.json", "w"), indent=1)
    print(f"optimized table: {ok} ok / {sk} skipped / {er} error")
    for c in out:
        if c["status"] == "error":
            print("ERROR:", c["arch"], c["shape"], c.get("error", "")[:120])


if __name__ == "__main__":
    main()
