"""Cluster failover smoke: two REST heads, one SQLite catalog, SIGKILL.

Boots two ``python -m repro.core.rest`` processes sharing one SQLite
store over the store-polling bus, submits a batch of in-flight
workflows to head 1, SIGKILLs head 1 mid-run (no cleanup, no claim
release), and asserts that head 2 adopts the orphaned workflows and
drives EVERY request to ``finished`` — no request lost, none stuck.
Also checks /v1/cluster flips head 1 to dead while head 2 stays alive,
then scrapes /v1/metrics from the survivor and fails if the key
telemetry series are absent or zero.

Run from CI (cluster-smoke job) or by hand:

    PYTHONPATH=src python scripts/cluster_smoke.py
"""
import os
import signal
import subprocess
import sys
import tempfile
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.core.client import IDDSClient  # noqa: E402
from repro.core.obs import parse_exposition  # noqa: E402
from repro.core.spec import WorkflowSpec  # noqa: E402

N_REQUESTS = 8
CLAIM_TTL = 1.0


def boot_head(db: str, head_id: str) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return subprocess.Popen(
        [sys.executable, "-m", "repro.core.rest", "--port", "0",
         "--store", db, "--bus", "store", "--head-id", head_id,
         "--claim-ttl", str(CLAIM_TTL), "--legacy-routes", "off"],
        env=env, stdout=subprocess.PIPE, text=True)


def serving_url(p: subprocess.Popen, deadline_s: float = 30.0) -> str:
    t0 = time.time()
    while time.time() - t0 < deadline_s:
        line = p.stdout.readline()
        if not line:
            raise RuntimeError("head exited before serving")
        print(f"  [head] {line.rstrip()}")
        if "serving on " in line:
            return line.split("serving on ", 1)[1].split()[0]
    raise RuntimeError("head did not report its URL in time")


def build_workflow(i: int):
    # slow enough that the SIGKILL lands mid-run (inline execution in
    # head 1's Carrier thread)
    spec = WorkflowSpec(f"smoke-{i}")
    spec.work("crunch", payload="sleep_ms", defaults={"ms": 120},
              start=[{}, {}])
    return spec.build()


def main() -> int:
    tmp = tempfile.mkdtemp(prefix="cluster-smoke-")
    db = os.path.join(tmp, "cluster.db")
    print(f"catalog: {db}")
    h1 = boot_head(db, "head-1")
    url1 = serving_url(h1)
    h2 = boot_head(db, "head-2")
    url2 = serving_url(h2)
    try:
        c1 = IDDSClient(url1)
        c2 = IDDSClient(url2)
        rids = [c1.submit_workflow(build_workflow(i),
                                   requester="cluster-smoke")
                for i in range(N_REQUESTS)]
        print(f"submitted {len(rids)} requests to head 1")

        # wait until head 1 actually owns in-flight work...
        deadline = time.time() + 30
        while time.time() < deadline:
            heads = {h["head_id"]: h
                     for h in c2.cluster()["heads"]}
            if heads.get("head-1", {}).get("claims", 0) > 0:
                break
            time.sleep(0.05)
        else:
            raise RuntimeError("head 1 never claimed any workflow")
        print(f"head 1 claims mid-run: {heads['head-1']['claims']} "
              f"-> SIGKILL")
        # ...then kill it dead: no claim release, no bus drain
        os.kill(h1.pid, signal.SIGKILL)
        h1.wait(timeout=10)

        # the survivor must adopt and finish EVERY request
        deadline = time.time() + 120
        pending = set(rids)
        while pending and time.time() < deadline:
            for rid in sorted(pending):
                if c2.status(rid)["status"] == "finished":
                    pending.discard(rid)
            time.sleep(0.2)
        if pending:
            raise RuntimeError(
                f"{len(pending)} requests never finished on the "
                f"survivor: {sorted(pending)}")
        print(f"survivor finished all {len(rids)} requests")

        # the health plane must show the dead head as dead
        deadline = time.time() + 30
        while time.time() < deadline:
            heads = {h["head_id"]: h
                     for h in c2.cluster()["heads"]}
            if (not heads["head-1"]["alive"]
                    and heads["head-2"]["alive"]):
                break
            time.sleep(0.2)
        else:
            raise RuntimeError(f"cluster view never converged: {heads}")
        print("cluster view: head-1 dead, head-2 alive")

        # the survivor's exposition must carry the key series with
        # nonzero samples.  (Lease latency only exists under
        # --distributed heads — the execution plane here is inline —
        # so scheduler series are asserted in tests/test_obs.py.)
        series = parse_exposition(c2.metrics())
        for name in ("idds_rest_requests_total",
                     "idds_daemon_loop_seconds_count",
                     "idds_bus_lag_seconds_count"):
            total = sum((series.get(name) or {}).values())
            if total <= 0:
                raise RuntimeError(
                    f"survivor /v1/metrics missing or zero: {name} "
                    f"(got {total})")
            print(f"  metrics: {name} = {total:g}")
        # ?cluster=1 must parse too and tag the survivor's series with
        # its head label (head-1's last snapshot is stale by now and
        # correctly dropped)
        clustered = parse_exposition(c2.metrics(cluster=True))
        heads_seen = {dict(key).get("head")
                      for key in clustered.get(
                          "idds_rest_requests_total", {})}
        if "head-2" not in heads_seen:
            raise RuntimeError(
                f"clustered exposition lacks head-2 series: "
                f"{heads_seen}")
        print(f"  clustered metrics heads: {sorted(h for h in heads_seen if h)}")

        # the adopted workflows' traces must stitch spans across BOTH
        # heads: submitted on head-1, finished on head-2
        tr = c2.trace(rids[0])
        if not tr["spans"]:
            raise RuntimeError(f"trace for {rids[0]} has no spans: {tr}")
        bad = [s for s in tr["spans"] if s["duration_s"] < 0]
        if bad:
            raise RuntimeError(f"negative-duration spans: {bad}")
        trace_heads = set(tr["heads"])
        if not {"head-1", "head-2"} <= trace_heads:
            raise RuntimeError(
                f"trace should carry events from both heads, got "
                f"{sorted(trace_heads)}")
        print(f"  trace {tr['trace_id']}: {len(tr['spans'])} spans "
              f"across heads {sorted(trace_heads)}")
        print("CLUSTER SMOKE PASSED")
        return 0
    finally:
        for p in (h1, h2):
            if p.poll() is None:
                p.send_signal(signal.SIGTERM)
        for p in (h1, h2):
            try:
                p.wait(timeout=15)
            except subprocess.TimeoutExpired:
                p.kill()


if __name__ == "__main__":
    sys.exit(main())
