#!/usr/bin/env python
"""AST lint: no ``time.time()`` for deadlines/durations in the core.

Wall-clock time jumps (NTP slews, suspend/resume, operators fixing the
date); a deadline or a duration computed from it silently corrupts —
leases expire early, hedges fire spuriously, daemon intervals stall.
Every hot-loop clock read in ``src/repro/core`` (and the carousel's
timing paths) must use ``time.monotonic()``.

Wall clock is still CORRECT for anything journaled or compared across
processes: catalog timestamps (``submitted_at``, ``created_at``,
``processed_at``), health heartbeats and claim expiries that peer heads
read from the shared store, bus publish timestamps (cross-process lag),
and trace-event timestamps.  Those call sites are allowlisted below by
``(file, enclosing qualname)`` — stable against line drift, and a new
``time.time()`` anywhere else fails CI until a human decides which
clock the new code actually needs.  Stale entries (the call site moved
or vanished) fail too, so the list keeps documenting real code.

    PYTHONPATH=src python scripts/check_monotonic.py
"""
from __future__ import annotations

import ast
import pathlib
from typing import Dict, List, Set, Tuple

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src/repro/core", "src/repro/carousel", "src/repro/worker")

# (file relative to src/repro, enclosing qualname) -> why wall clock is
# right there.  "<module>" covers module-level calls.
ALLOWLIST: Dict[Tuple[str, str], str] = {
    # journaled catalog timestamps (operators read these as dates)
    ("core/commands.py", "Command.from_dict"): "created_at journal field",
    ("core/requests.py", "Request.from_json"): "created_at journal field",
    ("core/workflow.py", "FileRef.__post_init__"): "created_at field",
    ("core/workflow.py", "FileRef.set_status"): "updated_at field",
    ("core/delivery.py", "Delivery.set_status"): "updated_at field",
    ("core/delivery.py", "Subscription.from_dict"):
        "created_at journal field",
    ("core/delivery.py", "outbox_message"):
        "created_at journal field",
    ("core/daemons.py", "Transformer._finalize"):
        "terminated_at journal field",
    ("core/daemons.py", "Commander.process_once"):
        "processed_at journal field",
    ("core/daemons.py", "Commander._apply_abort"):
        "processed_at journal field",
    ("core/idds.py", "IDDS.submit"): "submitted_at journal field",
    # cross-process comparisons through the shared store: peer heads
    # compare against THEIR wall clocks, monotonic is not comparable
    ("core/daemons.py", "Context.try_own"): "claim expiry vs peers",
    ("core/daemons.py", "Watchdog.__init__"): "started_at health field",
    ("core/daemons.py", "Watchdog._heartbeat"):
        "health heartbeat vs peers",
    ("core/daemons.py", "Watchdog._sweep"): "claim expiry vs peers",
    ("core/daemons.py", "Publisher.process_once"):
        "not_before ripeness + journaled attempt timestamps vs peers",
    ("core/idds.py", "IDDS.cluster_info"): "heartbeat age vs peers",
    ("core/idds.py", "IDDS.metrics_text"): "heartbeat age vs peers",
    ("core/idds.py", "IDDS.ack_delivery"):
        "notify-to-ack latency across heads",
    ("core/idds.py", "IDDS._on_notify"):
        "publish timestamp for publish-to-ack latency",
    ("core/store.py", "InMemoryStore.try_claim"): "claim expiry",
    ("core/store.py", "InMemoryStore.renew_claims"): "claim expiry",
    ("core/store.py", "SqliteStore.try_claim"): "claim expiry",
    ("core/store.py", "SqliteStore.renew_claims"): "claim expiry",
    ("core/scheduler.py", "JobScheduler._lease_journal_row"):
        "journaled lease expiry read by peers",
    # bus rows travel between processes: created_at/not_before and the
    # publish->consume lag are wall-clock by design
    ("core/messaging.py", "LocalBus.publish"): "message timestamp",
    ("core/messaging.py", "StorePollingBus.publish"): "message timestamp",
    ("core/messaging.py", "StorePollingBus.requeue"):
        "redelivery not_before",
    ("core/messaging.py", "StorePollingBus._to_messages"):
        "fallback message timestamp",
    ("core/messaging.py", "StorePollingBus.prune"): "retention horizon",
    ("core/messaging.py", "BusBackend._observe_lag"):
        "cross-process publish-to-consume lag",
    ("core/store.py", "InMemoryStore.bus_publish"): "message timestamp",
    ("core/store.py", "InMemoryStore.bus_consume"): "not_before gate",
    ("core/store.py", "InMemoryStore.bus_depth"): "not_before gate",
    ("core/store.py", "SqliteStore.bus_publish"): "message timestamp",
    ("core/store.py", "SqliteStore.bus_consume"): "not_before gate",
    ("core/store.py", "SqliteStore.bus_depth"): "not_before gate",
    # telemetry: trace events are journaled and merged across heads
    ("core/obs.py", "Tracer.emit"): "trace-event timestamp",
    # operator-facing wall-clock readouts (not deadlines)
    ("core/rest.py", "RestGateway.start"): "started_at readout",
    ("core/rest.py", "RestGateway.handle_healthz"): "uptime readout",
    ("core/dag.py", "DAGScheduler.run_sync"):
        "wall_s report field (single pass, not a deadline)",
}


def wall_clock_sites(path: pathlib.Path) -> List[Tuple[int, str]]:
    """Every ``time.time()`` call in the file as (line, qualname)."""
    tree = ast.parse(path.read_text(), filename=str(path))
    stack: List[str] = []
    sites: List[Tuple[int, str]] = []

    class Visitor(ast.NodeVisitor):
        def visit_FunctionDef(self, node):
            stack.append(node.name)
            self.generic_visit(node)
            stack.pop()

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_ClassDef(self, node):
            stack.append(node.name)
            self.generic_visit(node)
            stack.pop()

        def visit_Call(self, node):
            f = node.func
            if (isinstance(f, ast.Attribute) and f.attr == "time"
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "time"):
                sites.append((node.lineno, ".".join(stack) or "<module>"))
            self.generic_visit(node)

    Visitor().visit(tree)
    return sites


def main() -> int:
    errors: List[str] = []
    present: Set[Tuple[str, str]] = set()
    n_files = 0
    for d in SCAN_DIRS:
        for path in sorted((ROOT / d).glob("*.py")):
            n_files += 1
            rel = str(path.relative_to(ROOT / "src/repro"))
            for lineno, qualname in wall_clock_sites(path):
                key = (rel, qualname)
                present.add(key)
                if key not in ALLOWLIST:
                    errors.append(
                        f"{path}:{lineno}: time.time() in {qualname} — "
                        f"use time.monotonic() for deadlines/durations; "
                        f"if this is a journaled wall-clock field, "
                        f"allowlist {key!r} in "
                        f"scripts/check_monotonic.py")
    for key in sorted(ALLOWLIST):
        if key not in present:
            errors.append(f"stale allowlist entry {key!r}: no "
                          f"time.time() there any more — remove it")
    if errors:
        print("\n".join(errors))
        print(f"\ncheck_monotonic: {len(errors)} problem(s)")
        return 1
    print(f"check_monotonic: OK ({n_files} files scanned, "
          f"{len(ALLOWLIST)} allowlisted wall-clock sites)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
