"""Dev script: exercise the iDDS core end to end (sync + threaded)."""
import time

from repro.core import payloads as reg
from repro.core.active_learning import build_active_learning_workflow
from repro.core.dag import DAGScheduler, layered_dag
from repro.core.hpo import HPOService, loguniform, uniform
from repro.core.idds import IDDS
from repro.core.requests import Request
from repro.core.spec import WorkflowSpec


def test_simple_chain():
    reg.register_payload("smoke_double",
                         lambda params, inputs: {"x": params["x"] * 2})
    spec = WorkflowSpec("chain")
    a = spec.work("a", payload="smoke_double", start={"x": 3})
    a.then(spec.work("b", payload="smoke_double"))
    wf = spec.build()

    idds = IDDS()
    rid = idds.submit(Request(workflow=wf).to_json())
    idds.pump()
    info = idds.request_status(rid)
    assert info["status"] == "finished", info
    server_wf = idds.get_workflow(rid)
    vals = sorted(w.result["x"] for w in server_wf.works.values())
    # b re-doubles the same bound x: binder identity keeps x=3
    assert vals == [6, 6], vals
    print("[ok] chain:", info["works"], "stats:", idds.stats)


def test_active_learning():
    reg.register_payload(
        "smoke_al_process",
        lambda params, inputs: {"metric": 1.0 / (1 + params["round"])})
    reg.register_payload(
        "smoke_al_decide",
        lambda params, inputs: {
            "decision": params["processing_result"]["metric"] > 0.26,
            "hint": {"lr": 0.1 * (1 + params["round"])},
        })
    wf = build_active_learning_workflow(
        process_payload="smoke_al_process", decide_payload="smoke_al_decide",
        max_iterations=10)
    idds = IDDS()
    rid = idds.submit_workflow(wf)
    idds.pump()
    server_wf = idds.get_workflow(rid)
    templates = [w.template for w in server_wf.works.values()]
    n_proc = templates.count("process")
    # rounds 0..3: metric 1.0, .5, .333, .25 -> stops after round 3
    assert n_proc == 4, (n_proc, templates)
    print("[ok] active-learning:", server_wf.counts())


def test_dag(n=2000):
    idds = IDDS()
    jobs = layered_dag(n, width=50, fan_in=3)
    sched = DAGScheduler(idds, jobs)
    out = sched.run_sync()
    assert out["jobs"] == n == out["released"], out
    print(f"[ok] dag: {out}")


def test_hpo():
    reg.register_payload(
        "smoke_hpo_eval",
        lambda params, inputs: {
            "objective": ((params["lr"] - 0.01) ** 2
                          + (params["wd"] - 0.5) ** 2)})
    idds = IDDS()
    svc = HPOService(
        idds, {"lr": loguniform(1e-4, 1.0), "wd": uniform(0, 1)},
        eval_payload="smoke_hpo_eval", optimizer="evolution",
        points_per_round=8, max_points=48, seed=0)
    res = svc.run()
    assert len(res.trials) == 48
    assert res.best_objective < 0.05, res.best_objective
    print(f"[ok] hpo: best={res.best_objective:.5f} at {res.best_point}")


def test_threaded():
    reg.register_payload("smoke_sleepy",
                         lambda params, inputs: (time.sleep(0.01),
                                                 {"i": params["i"]})[1])
    spec = WorkflowSpec("threaded")
    spec.work("t", payload="smoke_sleepy",
              start=[{"i": i} for i in range(16)])
    wf = spec.build()
    idds = IDDS(sync=False, max_workers=8)
    idds.start()
    try:
        rid = idds.submit_workflow(wf)
        info = idds.wait_request(rid, timeout=30)
        assert info["works"].get("finished") == 16, info
    finally:
        idds.stop()
    print("[ok] threaded:", info["works"])


def test_retries():
    state = {"n": 0}

    def flaky(params, inputs):
        state["n"] += 1
        if state["n"] < 3:
            raise RuntimeError("transient")
        return {"ok": True}

    reg.register_payload("smoke_flaky", flaky)
    spec = WorkflowSpec("flaky")
    spec.work("f", payload="smoke_flaky", max_attempts=5, start={})
    wf = spec.build()
    idds = IDDS()
    idds.submit_workflow(wf)
    idds.pump()
    assert idds.stats["job_attempts"] == 3, idds.stats
    assert idds.stats.get("processings_failed", 0) == 0
    print("[ok] retries:", idds.stats)


if __name__ == "__main__":
    test_simple_chain()
    test_active_learning()
    test_dag()
    test_hpo()
    test_retries()
    test_threaded()
    print("core smoke passed")
