"""Benchmark regression gate: diff two BENCH_*.json artifacts.

Compares the gate metrics of a current benchmark results file (the
``benchmarks.run --json-out`` shape) against a prior committed
``BENCH_N.json`` and fails when any metric regresses by more than the
threshold (default 20%).  Only metrics present in BOTH files are
gated, so a new section never fails the first run that introduces it,
and files from different modes (smoke vs quick vs full) are never
compared — the sweep sizes differ, so the numbers are not
commensurable.

    PYTHONPATH=src python scripts/bench_diff.py BENCH_9.json
    PYTHONPATH=src python scripts/bench_diff.py results.json \
        --against BENCH_8.json --threshold 0.3

With no ``--against``, the newest prior BENCH_*.json in the repo root
with the same mode is picked automatically; if none matches, the gate
passes with a notice (first artifact of its mode).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import Dict, Optional, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# metric -> direction: "higher" is better or "lower" is better
HIGHER, LOWER = "higher", "lower"


def _rows(sections: Dict, name: str):
    rows = sections.get(name)
    return rows if isinstance(rows, list) else []


def gate_metrics(doc: Dict) -> Dict[str, Tuple[float, str]]:
    """Extract the gated scalars from one results file.  Every
    extractor is defensive: a missing section or row simply yields no
    metric (and therefore no comparison)."""
    s = doc.get("sections", {})
    out: Dict[str, Tuple[float, str]] = {}

    for r in _rows(s, "store"):
        m = re.fullmatch(r"(memory|sqlite)-bulk", str(r.get("store")))
        if m and r.get("rows_per_s"):
            out[f"store.{m.group(1)}-bulk.rows_per_s"] = (
                r["rows_per_s"], HIGHER)

    rest = [r.get("sub_per_s") for r in _rows(s, "rest")
            if r.get("sub_per_s")]
    if rest:
        out["rest.max_sub_per_s"] = (max(rest), HIGHER)

    dag = [r for r in _rows(s, "dag") if r.get("jobs_per_s")]
    if dag:
        # the smallest sweep exists in every mode
        smallest = min(dag, key=lambda r: r.get("jobs", 0))
        out["dag.jobs_per_s"] = (smallest["jobs_per_s"], HIGHER)

    worker = [r.get("jobs_per_s") for r in _rows(s, "worker")
              if r.get("jobs_per_s")]
    if worker:
        out["worker.max_jobs_per_s"] = (max(worker), HIGHER)

    for r in _rows(s, "delivery"):
        if r.get("mode") == "journal-sqlite-bulk" \
                and r.get("contents_per_s"):
            out["delivery.sqlite-bulk.contents_per_s"] = (
                r["contents_per_s"], HIGHER)

    cluster = [r.get("agg_sub_per_s") for r in _rows(s, "cluster")
               if r.get("agg_sub_per_s")]
    if cluster:
        out["cluster.max_agg_sub_per_s"] = (max(cluster), HIGHER)

    command = [r.get("rt_p50_ms") for r in _rows(s, "command")
               if r.get("rt_p50_ms")]
    if command:
        out["command.min_rt_p50_ms"] = (min(command), LOWER)

    for r in _rows(s, "obs"):
        if r.get("arm") == "e2e-metrics" and r.get("telemetry") == "on" \
                and r.get("overhead_pct") is not None:
            out["obs.e2e-metrics.overhead_pct"] = (
                max(r["overhead_pct"], 0.1), LOWER)

    for r in _rows(s, "intel"):
        if r.get("arm") == "on" and r.get("p99_ttd_s"):
            out["intel.on.p99_ttd_s"] = (r["p99_ttd_s"], LOWER)
        if r.get("arm") == "on" and r.get("makespan_s"):
            out["intel.on.makespan_s"] = (r["makespan_s"], LOWER)

    for r in _rows(s, "outbox"):
        if r.get("arm") == "long-poll" and r.get("p50_ms"):
            out["outbox.long-poll.p50_ms"] = (r["p50_ms"], LOWER)
        if r.get("arm") == "webhook" and r.get("p50_ms"):
            out["outbox.webhook.p50_ms"] = (r["p50_ms"], LOWER)
        if r.get("arm") == "fanout-batched" \
                and r.get("deliveries_per_s"):
            out["outbox.fanout-batched.deliveries_per_s"] = (
                r["deliveries_per_s"], HIGHER)

    return out


def check_intel_invariants(doc: Dict):
    """Intra-file acceptance checks on the intel section (no baseline
    needed): with the intelligence plane on, p99 time-to-delivered must
    strictly beat the FIFO arm of the same run, and the affinity
    hit-rate must be positive (the routing actually fired).  Returns a
    list of violation strings; empty when the section is absent."""
    arms = {r.get("arm"): r for r in _rows(doc.get("sections", {}), "intel")}
    on, off = arms.get("on"), arms.get("off")
    if not on or not off:
        return []
    bad = []
    if not on.get("p99_ttd_s") or not off.get("p99_ttd_s") \
            or on["p99_ttd_s"] >= off["p99_ttd_s"]:
        bad.append(f"intel-on p99_ttd_s ({on.get('p99_ttd_s')}) must be "
                   f"strictly below intel-off ({off.get('p99_ttd_s')})")
    hit = on.get("affinity_hit_rate")
    if not isinstance(hit, (int, float)) or hit <= 0:
        bad.append(f"intel-on affinity_hit_rate ({hit!r}) must be > 0")
    return bad


def pick_baseline(current_path: str, mode: str) -> Optional[str]:
    """The newest committed BENCH_N.json (by N) with the same mode,
    excluding the file under test."""
    best = None
    for path in glob.glob(os.path.join(ROOT, "BENCH_*.json")):
        if os.path.abspath(path) == os.path.abspath(current_path):
            continue
        m = re.search(r"BENCH_(\d+)\.json$", path)
        if not m:
            continue
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            continue
        if doc.get("mode") != mode:
            continue
        n = int(m.group(1))
        if best is None or n > best[0]:
            best = (n, path)
    return best[1] if best else None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="results file under test")
    ap.add_argument("--against", default=None,
                    help="baseline BENCH_*.json (default: newest "
                         "committed file with the same mode)")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max tolerated fractional regression "
                         "(default 0.20 = 20%%)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)

    # intra-file gate first: the intel arm must pay for itself within
    # this very run, baseline or not
    intel_bad = check_intel_invariants(current)
    for msg in intel_bad:
        print(f"  INTEL GATE: {msg}")
    if intel_bad:
        print(f"\nFAIL: intel section violates "
              f"{len(intel_bad)} invariant(s)")
        return 1

    baseline_path = args.against or pick_baseline(
        args.current, current.get("mode"))
    if baseline_path is None:
        print(f"no prior BENCH_*.json with mode="
              f"{current.get('mode')!r}; nothing to gate")
        return 0
    with open(baseline_path) as f:
        baseline = json.load(f)
    if baseline.get("mode") != current.get("mode"):
        print(f"mode mismatch ({baseline.get('mode')} vs "
              f"{current.get('mode')}): sweeps are not commensurable, "
              f"nothing to gate")
        return 0

    cur, base = gate_metrics(current), gate_metrics(baseline)
    shared = sorted(set(cur) & set(base))
    print(f"gating {os.path.basename(args.current)} against "
          f"{os.path.basename(baseline_path)} "
          f"(mode={current.get('mode')}, threshold "
          f"{args.threshold:.0%}, {len(shared)} shared metrics)")
    failures = []
    for name in shared:
        (cv, direction), (bv, _) = cur[name], base[name]
        if direction == HIGHER:
            change = (cv - bv) / bv          # negative = regression
        else:
            change = (bv - cv) / bv          # slower/bigger = negative
        flag = "REGRESSION" if change < -args.threshold else "ok"
        print(f"  {name:45s} {bv:>12g} -> {cv:>12g}  "
              f"({change:+.1%}, {direction} is better) {flag}")
        if change < -args.threshold:
            failures.append(name)
    skipped = sorted((set(cur) | set(base)) - set(shared))
    if skipped:
        print(f"  not in both files (skipped): {', '.join(skipped)}")
    if failures:
        print(f"\nFAIL: {len(failures)} metric(s) regressed past "
              f"{args.threshold:.0%}: {', '.join(failures)}")
        return 1
    print("\nbench diff: no gated regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
