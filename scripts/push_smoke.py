"""Push-delivery smoke: SSE + webhook consumers surviving a SIGKILL.

Boots two ``python -m repro.core.rest`` processes on one SQLite
catalog (store-polling bus), registers an SSE subscription and a
webhook subscription, and starts a live SSE consumer against head 1
plus an in-process webhook receiver.  Mid-stream — after the first
notifications have flowed — head 1 is SIGKILLed with no cleanup, more
work is submitted to head 2, and the smoke asserts the push plane's
crash contract end to end:

  * the SSE consumer reconnects to head 2 with ``Last-Event-ID`` and
    the journaled event stream carries EVERY delivery exactly once
    (seq cursor strictly increasing, no gaps against the catalog);
  * head 2's Publisher adopts the outbox claim and keeps POSTing —
    every webhook message lands despite head 1 dying (duplicates on
    the wire allowed, loss not);
  * the survivor's /v1/metrics exposition shows the outbox series.

Run from CI (push-smoke job) or by hand:

    PYTHONPATH=src python scripts/push_smoke.py
"""
import json
import os
import signal
import subprocess
import sys
import tempfile
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.core.client import IDDSClient  # noqa: E402
from repro.core.obs import parse_exposition  # noqa: E402
from repro.core.spec import WorkflowSpec  # noqa: E402

CLAIM_TTL = 1.0
WAVES = (3, 3)  # deliveries before the kill, after the kill


def boot_head(db: str, head_id: str) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return subprocess.Popen(
        [sys.executable, "-m", "repro.core.rest", "--port", "0",
         "--store", db, "--bus", "store", "--head-id", head_id,
         "--claim-ttl", str(CLAIM_TTL), "--legacy-routes", "off"],
        env=env, stdout=subprocess.PIPE, text=True)


def serving_url(p: subprocess.Popen, deadline_s: float = 30.0) -> str:
    t0 = time.time()
    while time.time() - t0 < deadline_s:
        line = p.stdout.readline()
        if not line:
            raise RuntimeError("head exited before serving")
        print(f"  [head] {line.rstrip()}")
        if "serving on " in line:
            return line.split("serving on ", 1)[1].split()[0]
    raise RuntimeError("head did not report its URL in time")


def build_workflow(wave: str, n: int):
    # one work per output collection: every job lands one distinct
    # output file, so every matching subscription gets n deliveries
    spec = WorkflowSpec(f"push-{wave}")
    for i in range(n):
        spec.work(f"crunch{i}", payload="sleep_ms",
                  defaults={"ms": 40},
                  output_collection=f"out.push.{wave}{i}", start=[{}])
    return spec.build()


class Receiver:
    """Webhook endpoint: records every accepted msg_id."""

    def __init__(self):
        self.accepted = []
        self.delivery_ids = set()
        self.lock = threading.Lock()
        recv = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, fmt, *args):  # noqa: A003
                pass

            def do_POST(self):  # noqa: N802
                length = int(self.headers.get("Content-Length", 0) or 0)
                body = json.loads(self.rfile.read(length))
                with recv.lock:
                    for d in body.get("deliveries", []):
                        recv.accepted.append(d["msg_id"])
                        recv.delivery_ids.add(d["delivery_id"])
                self.send_response(200)
                self.send_header("Content-Length", "2")
                self.end_headers()
                self.wfile.write(b"{}")

        self.httpd = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
        threading.Thread(target=self.httpd.serve_forever,
                         daemon=True).start()

    @property
    def url(self) -> str:
        return f"http://127.0.0.1:{self.httpd.server_address[1]}/hook"

    def close(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()


class SSEConsumer:
    """Follows a subscription's event stream, reconnecting with the
    last seen seq as the resume cursor — first against head 1, then
    (once it dies mid-stream) against whatever URL ``retarget`` set."""

    def __init__(self, url: str, sub_id: str):
        self.url = url
        self.sub_id = sub_id
        self.events = []
        self.last_seq = None
        self.reconnects = 0
        self.lock = threading.Lock()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def retarget(self, url: str) -> None:
        self.url = url

    def _run(self) -> None:
        while not self._stop.is_set():
            client = IDDSClient(self.url, timeout=5.0)
            try:
                for ev in client.events(self.sub_id,
                                        after_seq=self.last_seq,
                                        wait_s=5.0):
                    with self.lock:
                        self.events.append(ev)
                        self.last_seq = ev["seq"]
            except Exception:  # noqa: BLE001 — severed stream: resume
                pass
            with self.lock:
                self.reconnects += 1
            self._stop.wait(0.1)

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=10)


def _await(predicate, what: str, deadline_s: float = 60.0,
           snapshot=None):
    t0 = time.time()
    while time.time() - t0 < deadline_s:
        got = predicate()
        if got:
            return got
        time.sleep(0.1)
    detail = f" ({snapshot()})" if snapshot else ""
    raise RuntimeError(f"timed out waiting for {what}{detail}")


def _ack_all(client: IDDSClient, sub_id: str) -> set:
    """Acknowledge every un-acked delivery (what a real consumer does
    after processing — stops the Conductor's un-acked retry stream).
    Returns the acked delivery_ids: acking prunes them from the
    subscription's listing, so this is the caller's record."""
    pending = [d["delivery_id"]
               for d in client.list_deliveries(sub_id)["deliveries"]
               if d["status"] != "acked"]
    if pending:
        client.ack(sub_id, pending)
    return set(pending)


def main() -> int:
    tmp = tempfile.mkdtemp(prefix="push-smoke-")
    db = os.path.join(tmp, "push.db")
    print(f"catalog: {db}")
    recv = Receiver()
    h1 = boot_head(db, "head-1")
    url1 = serving_url(h1)
    h2 = boot_head(db, "head-2")
    url2 = serving_url(h2)
    consumer = None
    try:
        c1, c2 = IDDSClient(url1), IDDSClient(url2)
        sse_sub = c1.subscribe("sse-consumer", ["out.push.*"])
        hook_sub = c1.subscribe("hooked", ["out.push.*"],
                                push_url=recv.url)
        print(f"subscribed: sse={sse_sub['sub_id']} "
              f"webhook={hook_sub['sub_id']}")
        consumer = SSEConsumer(url1, sse_sub["sub_id"])

        # the Conductor re-notifies un-acked deliveries (new msg rows,
        # same delivery_id), so progress is counted in distinct
        # deliveries, not raw events
        def sse_covered():
            with consumer.lock:
                return len({ev["delivery_id"]
                            for ev in consumer.events})

        # wave 1 through head 1: the stream must flow live
        c1.submit_workflow(build_workflow("a", WAVES[0]),
                           requester="push-smoke")
        _await(lambda: sse_covered() >= WAVES[0],
               "wave-1 SSE events",
               snapshot=lambda: (
                   f"events={consumer.events} reconnects="
                   f"{consumer.reconnects} catalog="
                   f"{c1.list_deliveries(sse_sub['sub_id'])}"))
        _await(lambda: len(recv.delivery_ids) >= WAVES[0],
               "wave-1 webhook deliveries")
        sse_tracked = _ack_all(c1, sse_sub["sub_id"])
        hook_tracked = _ack_all(c1, hook_sub["sub_id"])
        print(f"wave 1 flowed: {len(consumer.events)} SSE events, "
              f"{len(set(recv.accepted))} webhook msgs -> SIGKILL "
              f"head 1 mid-stream")

        # head 1 dies with the SSE socket open and the outbox claim
        # held; no cleanup, no handoff
        os.kill(h1.pid, signal.SIGKILL)
        h1.wait(timeout=10)
        consumer.retarget(url2)

        # wave 2 through the survivor: adoption must keep both
        # channels flowing — the SSE consumer resumes past its cursor,
        # the Publisher claim moves to head 2
        c2.submit_workflow(build_workflow("b", WAVES[1]),
                           requester="push-smoke")
        total = sum(WAVES)
        _await(lambda: sse_covered() >= total,
               "post-kill SSE resume", deadline_s=90)
        _await(lambda: len(recv.delivery_ids) >= total,
               "post-kill webhook adoption", deadline_s=90)
        sse_tracked |= _ack_all(c2, sse_sub["sub_id"])
        hook_tracked |= _ack_all(c2, hook_sub["sub_id"])
        consumer.stop()

        # exactly-once on the SSE journal: every journaled message
        # streamed once, cursor strictly increasing across reconnects
        seqs = [ev["seq"] for ev in consumer.events]
        if sorted(set(seqs)) != sorted(seqs) or seqs != sorted(seqs):
            raise RuntimeError(f"SSE stream replayed or reordered: {seqs}")
        if len(sse_tracked) != total:
            raise RuntimeError(
                f"expected {total} tracked deliveries, got "
                f"{len(sse_tracked)}: {sorted(sse_tracked)}")
        seen = {ev["delivery_id"] for ev in consumer.events}
        if seen != sse_tracked:
            raise RuntimeError(
                f"SSE stream lost deliveries: missing "
                f"{sorted(sse_tracked - seen)}")
        print(f"SSE: {len(consumer.events)} events (each journaled "
              f"message exactly once) across {consumer.reconnects} "
              f"reconnect(s), seq {seqs[0]}..{seqs[-1]}, covering all "
              f"{total} deliveries")

        # at-least-once on the webhook wire, zero loss
        if len(hook_tracked) != total:
            raise RuntimeError(
                f"expected {total} webhook deliveries, got "
                f"{len(hook_tracked)}")
        print(f"webhook: {len(set(recv.accepted))} distinct msgs "
              f"({len(recv.accepted)} posts) after adoption")

        series = parse_exposition(c2.metrics())
        delivered = sum(
            (series.get("idds_outbox_deliveries_total") or {}).values())
        if delivered <= 0:
            raise RuntimeError(
                "survivor exposition missing idds_outbox_deliveries_total")
        print(f"  metrics: idds_outbox_deliveries_total = {delivered:g}")
        print("PUSH SMOKE PASSED")
        return 0
    finally:
        if consumer is not None:
            consumer.stop()
        recv.close()
        for p in (h1, h2):
            if p.poll() is None:
                p.send_signal(signal.SIGTERM)
        for p in (h1, h2):
            try:
                p.wait(timeout=15)
            except subprocess.TimeoutExpired:
                p.kill()


if __name__ == "__main__":
    sys.exit(main())
