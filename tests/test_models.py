"""Per-arch smoke tests (assignment requirement): every architecture
instantiates a REDUCED config and runs one forward/train step + a
prefill/decode round on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (RunConfig, ShapeConfig, get_config,
                                get_smoke_config, list_archs)
from repro.models import registry
from repro.serve import engine
from repro.train.step import init_state, make_train_step

ARCHS = list_archs()
SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
RUN = RunConfig(total_steps=10, warmup_steps=2, ce_block_v=64)


def test_all_archs_registered():
    assert len(ARCHS) == 10
    expected = {"qwen1.5-32b", "yi-6b", "qwen1.5-4b", "starcoder2-15b",
                "mamba2-130m", "zamba2-1.2b", "qwen3-moe-235b-a22b",
                "mixtral-8x7b", "whisper-tiny", "llava-next-mistral-7b"}
    assert set(ARCHS) == expected


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    state = init_state(jax.random.PRNGKey(0), cfg, RUN)
    batch = registry.synth_inputs(jax.random.PRNGKey(1), cfg, SHAPE, "train")
    step = jax.jit(make_train_step(cfg, RUN))
    state2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0 < loss < 20
    # params actually changed (some leaf; bf16 may round tiny updates away)
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(state2["params"])))
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    state = init_state(jax.random.PRNGKey(0), cfg, RUN)
    pre = registry.synth_inputs(jax.random.PRNGKey(2), cfg, SHAPE, "prefill")
    extra = cfg.num_img_patches if cfg.family == "vlm" else 0
    cache = engine.init_cache(cfg, SHAPE.global_batch, 64 + extra)
    tok, cache = jax.jit(engine.make_prefill_step(cfg, RUN))(
        state["params"], pre, cache)
    assert tok.shape == (2, 1)
    dec = jax.jit(engine.make_decode_step(cfg, RUN))
    pos = jnp.asarray(SHAPE.seq_len + extra, jnp.int32)
    tok2, cache = dec(state["params"], tok, cache, pos)
    assert tok2.shape == (2, 1)
    assert bool(jnp.all((tok2 >= 0) & (tok2 < cfg.vocab_size)))


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-130m", "zamba2-1.2b"])
def test_decode_matches_prefill_logits(arch):
    """Greedy decode after prefill must agree with a longer prefill —
    cache correctness across families (attention, SSM, hybrid)."""
    cfg = get_smoke_config(arch)
    run = RUN
    params = init_state(jax.random.PRNGKey(0), cfg, run)["params"]
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 2,
                              cfg.vocab_size, jnp.int32)

    # full prefill over 16 tokens
    cache_a = engine.init_cache(cfg, 2, 32)
    logits_a, _ = registry.prefill(params, cfg, run,
                                   {"tokens": toks}, cache_a)

    # prefill 15 then decode token 15
    cache_b = engine.init_cache(cfg, 2, 32)
    _, cache_b = registry.prefill(params, cfg, run,
                                  {"tokens": toks[:, :15]}, cache_b)
    logits_b, _ = registry.decode(params, cfg, run, toks[:, 15:16],
                                  cache_b, jnp.asarray(15, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_a[:, -1], np.float32),
        np.asarray(logits_b[:, -1], np.float32), rtol=3e-2, atol=3e-2)


def test_loss_mask_zeroes_positions():
    cfg = get_smoke_config("yi-6b")
    params = init_state(jax.random.PRNGKey(0), cfg, RUN)["params"]
    batch = registry.synth_inputs(jax.random.PRNGKey(1), cfg, SHAPE, "train")
    from repro.train.loss import lm_loss
    l_full, _ = lm_loss(params, cfg, RUN, batch)
    batch2 = dict(batch)
    batch2["loss_mask"] = batch["loss_mask"].at[:, ::2].set(0.0)
    l_half, _ = lm_loss(params, cfg, RUN, batch2)
    assert not np.isclose(float(l_full), float(l_half))


def test_blockwise_ce_matches_direct():
    cfg = get_smoke_config("yi-6b")
    params = init_state(jax.random.PRNGKey(0), cfg, RUN)["params"]
    batch = registry.synth_inputs(jax.random.PRNGKey(1), cfg, SHAPE, "train")
    from repro.train.loss import lm_loss
    l_block, _ = lm_loss(params, cfg, RUN.replace(ce_mode="blockwise"),
                         batch)
    l_direct, _ = lm_loss(params, cfg, RUN.replace(ce_mode="direct"), batch)
    np.testing.assert_allclose(float(l_block), float(l_direct),
                               rtol=1e-4, atol=1e-5)


def test_blockwise_ce_gradients_match():
    cfg = get_smoke_config("qwen1.5-4b")
    run = RUN
    params = init_state(jax.random.PRNGKey(0), cfg, run)["params"]
    batch = registry.synth_inputs(jax.random.PRNGKey(1), cfg, SHAPE, "train")
    from repro.train.loss import lm_loss

    def lf(mode):
        return lambda p: lm_loss(p, cfg, run.replace(ce_mode=mode), batch)[0]

    g1 = jax.grad(lf("blockwise"))(params)
    g2 = jax.grad(lf("direct"))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-3)


def test_grad_accumulation_equivalence():
    cfg = get_smoke_config("yi-6b")
    batch = registry.synth_inputs(jax.random.PRNGKey(1), cfg,
                                  ShapeConfig("s", 16, 4, "train"), "train")
    from repro.train.step import grads_and_metrics
    params = init_state(jax.random.PRNGKey(0), cfg, RUN)["params"]
    g1, m1 = grads_and_metrics(params, cfg, RUN.replace(accum_steps=1),
                               batch)
    g2, m2 = grads_and_metrics(params, cfg, RUN.replace(accum_steps=4),
                               batch)
    # same data, different microbatching -> same mean loss & close grads
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)


def test_full_configs_match_assignment():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size, cfg.num_experts,
            cfg.num_experts_per_tok) == (94, 4096, 64, 4, 1536, 151936,
                                         128, 8)
    cfg = get_config("starcoder2-15b")
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (40, 6144, 48, 4, 24576, 49152)
    cfg = get_config("mamba2-130m")
    assert (cfg.num_layers, cfg.d_model, cfg.vocab_size,
            cfg.ssm_state) == (24, 768, 50280, 128)
