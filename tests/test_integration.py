"""End-to-end integration: carousel-fed training, resume, coarse-vs-fine
time-to-first-batch, serving driver, iDDS-orchestrated training Works."""

import numpy as np

from repro.core import payloads as reg
from repro.core.idds import IDDS
from repro.launch.serve import run_serving
from repro.launch.train import run_training


def test_training_loss_decreases_with_carousel():
    res = run_training("yi-6b", smoke=True, steps=30, seq_len=32,
                       global_batch=4, carousel=True)
    assert res["steps"] == 30
    first = np.mean(res["losses"][:5])
    last = np.mean(res["losses"][-5:])
    assert last < first, (first, last)


def test_training_fine_starts_before_coarse():
    """With a slow single-drive tape, fine granularity trains on shard 1
    while shards 2..8 are still staging; coarse waits for all of them."""
    kw = dict(smoke=True, steps=6, seq_len=32, global_batch=2,
              carousel=True, tape_latency=0.4, drives=1)
    fine = run_training("qwen1.5-4b", coarse=False, **kw)
    coarse = run_training("qwen1.5-4b", coarse=True, **kw)
    # 8 shards x 0.4s on one drive: coarse must wait ~2.8s longer
    assert (coarse["time_to_first_batch_s"]
            > fine["time_to_first_batch_s"] + 1.5)


def test_resume_continues_from_checkpoint(tmp_path):
    out = str(tmp_path / "run")
    r1 = run_training("mamba2-130m", smoke=True, steps=10, seq_len=32,
                      global_batch=2, out_dir=out, ckpt_every=5)
    assert r1["final_step"] == 10
    r2 = run_training("mamba2-130m", smoke=True, steps=5, seq_len=32,
                      global_batch=2, out_dir=out, resume=True,
                      ckpt_every=5)
    assert r2["final_step"] == 15


def test_serving_driver():
    res = run_serving("yi-6b", smoke=True, prompt_len=16, gen=8, batch=2)
    assert res["generated"] == (2, 8)
    toks = np.asarray(res["tokens"])
    assert (toks >= 0).all()


def test_idds_orchestrated_hpo_over_training():
    """The paper's HPO service driving REAL (tiny) training runs."""
    from repro.core.hpo import HPOService, loguniform
    from repro.configs.base import RunConfig

    def train_trial(params, inputs):
        run = RunConfig(learning_rate=float(params["lr"]),
                        warmup_steps=1, total_steps=8, ce_block_v=64)
        res = run_training("yi-6b", smoke=True, steps=8, seq_len=16,
                           global_batch=2, carousel=False, run=run)
        return {"objective": res["last_loss"]}

    reg.register_payload("i_train_trial", train_trial)
    idds = IDDS()
    svc = HPOService(idds, {"lr": loguniform(1e-5, 1e-1)},
                     eval_payload="i_train_trial", optimizer="halton",
                     points_per_round=2, max_points=4, seed=0)
    out = svc.run()
    assert len(out.trials) == 4
    assert np.isfinite(out.best_objective)
