"""HPO service (Fig. 6), Active Learning (Fig. 7), Rubin DAG (§3.3.1),
head-service auth (shared semantics with the REST gateway in test_rest)."""

import pytest

from repro.core import payloads as reg
from repro.core.active_learning import build_active_learning_workflow
from repro.core.dag import DAGScheduler, JobSpec, layered_dag
from repro.core.hpo import (HaltonSearch, HPOService, RandomSearch, choice,
                            integer, loguniform, uniform)
from repro.core.idds import IDDS, AuthError
from repro.core.workflow import Branch, Condition, Workflow, WorkTemplate


# ------------------------------------------------------------------ auth

def _noop_workflow() -> Workflow:
    wf = Workflow(name="auth-check")
    wf.add_template(WorkTemplate(name="n", payload="noop"))
    wf.add_initial("n", {})
    return wf


def test_auth_disabled_accepts_any_token():
    idds = IDDS()  # tokens=None -> dev mode
    for token in ("", "anything"):
        rid = idds.submit_workflow(_noop_workflow(), token=token)
        assert rid in idds._requests


def test_auth_rejects_bad_token():
    idds = IDDS(tokens={"good"})
    with pytest.raises(AuthError):
        idds.submit_workflow(_noop_workflow(), token="bad")
    with pytest.raises(AuthError):
        idds.submit_workflow(_noop_workflow())  # empty token
    assert idds._requests == {}  # nothing registered on auth failure


def test_auth_accepts_good_token():
    idds = IDDS(tokens={"good", "other"})
    rid = idds.submit_workflow(_noop_workflow(), token="good")
    idds.pump()
    assert idds.request_status(rid)["status"] == "finished"


# ------------------------------------------------- daemon fault isolation

def _two_step_workflow(name: str, predicate: str = "always") -> Workflow:
    wf = Workflow(name=name)
    wf.add_template(WorkTemplate(name="a", payload="noop"))
    wf.add_template(WorkTemplate(name="b", payload="noop"))
    wf.add_condition(Condition(trigger="a", predicate=predicate,
                               true_next=[Branch("b")]))
    wf.add_initial("a", {})
    return wf


def test_bad_predicate_does_not_drop_batched_messages(capsys):
    """The Marshaller drains T_WORK_DONE in batches: one workflow with a
    raising predicate must not discard a co-batched healthy workflow's
    message (which would wedge it at 'running' forever)."""
    idds = IDDS()
    rid_bad = idds.submit_workflow(
        _two_step_workflow("bad", predicate="never-registered"))
    rid_good = idds.submit_workflow(_two_step_workflow("good"))
    idds.pump()
    capsys.readouterr()  # swallow the printed predicate traceback
    good = idds.request_status(rid_good)
    assert good["status"] == "finished"
    assert good["works"] == {"finished": 2}
    # the bad workflow degrades (no successors) but is not wedged
    bad = idds.request_status(rid_bad)
    assert bad["status"] == "finished"
    assert bad["works"] == {"finished": 1}
    assert idds.stats["marshaller_errors"] == 1


# ------------------------------------------------------------------- HPO

def _quad(params, inputs):
    return {"objective": (params["lr"] - 0.2) ** 2
            + (params["wd"] - 0.7) ** 2}


def test_hpo_random_search_runs_budget():
    reg.register_payload("h_quad", _quad)
    idds = IDDS()
    svc = HPOService(idds, {"lr": uniform(0, 1), "wd": uniform(0, 1)},
                     eval_payload="h_quad", optimizer="random",
                     points_per_round=5, max_points=20, seed=1)
    res = svc.run()
    assert len(res.trials) == 20
    assert res.rounds == 4
    assert res.best_objective < 0.5


def test_hpo_evolution_beats_random():
    reg.register_payload("h_quad2", _quad)
    results = {}
    for opt in ("random", "evolution"):
        idds = IDDS()
        svc = HPOService(idds, {"lr": uniform(0, 1), "wd": uniform(0, 1)},
                         eval_payload="h_quad2", optimizer=opt,
                         points_per_round=8, max_points=64, seed=3)
        results[opt] = svc.run().best_objective
    assert results["evolution"] <= results["random"]


def test_hpo_async_evaluation():
    import time
    reg.register_payload(
        "h_slow", lambda p, i: (time.sleep(0.01), _quad(p, i))[1])
    idds = IDDS(sync=False, max_workers=8)
    idds.start()
    try:
        svc = HPOService(idds, {"lr": uniform(0, 1), "wd": uniform(0, 1)},
                         eval_payload="h_slow", optimizer="halton",
                         points_per_round=8, max_points=16, seed=0)
        t0 = time.time()
        res = svc.run(timeout=60)
        wall = time.time() - t0
    finally:
        idds.stop()
    assert len(res.trials) == 16
    # 16 evals x 10ms on 8 workers: async must beat serial time
    assert wall < 16 * 0.01 * 0.9 + 1.0


def test_hpo_failed_trials_counted():
    calls = {"n": 0}

    def sometimes(params, inputs):
        calls["n"] += 1
        if calls["n"] % 4 == 0:
            raise RuntimeError("trial crashed")
        return _quad(params, inputs)

    reg.register_payload("h_crashy", sometimes)
    idds = IDDS()
    svc = HPOService(idds, {"lr": uniform(0, 1), "wd": uniform(0, 1)},
                     eval_payload="h_crashy", optimizer="random",
                     points_per_round=4, max_points=12, seed=0)
    res = svc.run()
    assert len(res.trials) + res.failed_trials == 12


def test_search_space_dims():
    rnd = RandomSearch({"a": uniform(2, 3), "b": loguniform(1e-4, 1e-1),
                        "c": integer(1, 5), "d": choice("x", "y")}, seed=0)
    pts = rnd.ask(50)
    for p in pts:
        assert 2 <= p["a"] <= 3
        assert 1e-4 <= p["b"] <= 1e-1
        assert p["c"] in (1, 2, 3, 4, 5)
        assert p["d"] in ("x", "y")


def test_halton_low_discrepancy():
    h = HaltonSearch({"a": uniform(0, 1)}, seed=0)
    pts = [p["a"] for p in h.ask(64)]
    # quasi-random: every 1/8 bucket hit
    buckets = {int(p * 8) for p in pts}
    assert len(buckets) == 8


# ------------------------------------------------------- Active Learning

def test_active_learning_cycles_until_stop():
    hist = []

    def process(params, inputs):
        hist.append(params.get("lr", 0.1))
        return {"metric": abs(params.get("lr", 0.1) - 0.4)}

    def decide(params, inputs):
        m = params["processing_result"]["metric"]
        return {"decision": m > 0.05,
                "hint": {"lr": params.get("lr", 0.1) + 0.1}}

    reg.register_payload("al_p", process)
    reg.register_payload("al_d", decide)
    wf = build_active_learning_workflow(
        process_payload="al_p", decide_payload="al_d",
        init_params={"lr": 0.1}, max_iterations=20)
    idds = IDDS()
    rid = idds.submit_workflow(wf)
    idds.pump()
    # lr walks 0.1 -> 0.2 -> 0.3 -> 0.4 then stops (metric 0.0 <= 0.05)
    assert hist == pytest.approx([0.1, 0.2, 0.3, 0.4])
    server_wf = idds.get_workflow(rid)
    assert server_wf.finished


def test_active_learning_max_iterations_bound():
    reg.register_payload("al_p2", lambda p, i: {"metric": 1.0})
    reg.register_payload("al_d2", lambda p, i: {"decision": True,
                                                "hint": {}})
    wf = build_active_learning_workflow(
        process_payload="al_p2", decide_payload="al_d2", max_iterations=3)
    idds = IDDS()
    rid = idds.submit_workflow(wf)
    idds.pump()  # must terminate despite decision always True
    assert idds.get_workflow(rid).finished


# ------------------------------------------------------------- Rubin DAG

def test_dag_dependency_order():
    order = []
    reg.register_payload("dag_rec", lambda p, i: (order.append(p["job_id"]),
                                                  {})[1])
    jobs = [
        JobSpec("a", payload="dag_rec"),
        JobSpec("b", payload="dag_rec", deps=("a",)),
        JobSpec("c", payload="dag_rec", deps=("a",)),
        JobSpec("d", payload="dag_rec", deps=("b", "c")),
    ]
    idds = IDDS()
    sched = DAGScheduler(idds, jobs)
    out = sched.run_sync()
    assert out["jobs"] == 4
    assert order.index("a") < order.index("b")
    assert order.index("a") < order.index("c")
    assert order.index("d") == 3


def test_dag_incremental_release():
    """Jobs are only released when deps complete (never all upfront)."""
    jobs = layered_dag(300, width=30, fan_in=2, seed=5)
    idds = IDDS()
    sched = DAGScheduler(idds, jobs)
    sched.submit()
    assert sched.released == 30  # only the first layer
    while not sched.finished:
        moved = sum(d.process_once() for d in idds.daemons)
        assert moved > 0
    assert sched.released == 300


def test_dag_rejects_unknown_dep():
    with pytest.raises(KeyError):
        DAGScheduler(IDDS(), [JobSpec("a", deps=("ghost",))])


def test_dag_rejects_rootless():
    jobs = [JobSpec("a", deps=("b",)), JobSpec("b", deps=("a",))]
    with pytest.raises(ValueError):
        DAGScheduler(IDDS(), jobs).submit()
