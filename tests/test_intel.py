"""Intelligence plane: learned history + locality-aware dispatch.

Covers the RollingPercentile primitive, the HistoryBook / AffinityIndex
/ IntelPlane brain, the stats table on both store backends (including
the journal op and the write-coalescing buffer), the Conductor's
learned-p95 hedge pass, the Watchdog's adaptive-reprioritization
housekeeping, and the /v1/intel + /v1/queues REST surface with the
worker manifest riding lease and heartbeat calls.
"""
import time

import pytest

from repro.carousel.ddm import CarouselDDM
from repro.carousel.stager import StageRecord, Stager
from repro.carousel.storage import ColdStore, DiskCache, TapeFile
from repro.core.client import IDDSClient
from repro.core.daemons import Conductor, Watchdog
from repro.core.idds import IDDS
from repro.core.intel import AffinityIndex, HistoryBook, IntelPlane
from repro.core.obs import RollingPercentile
from repro.core.rest import RestGateway
from repro.core.scheduler import DistributedWFM, JobScheduler
from repro.core.store import BufferedStore, InMemoryStore, SqliteStore
from repro.core.workflow import Processing


def _proc(pid, queue="default", priority=0, files=()):
    return Processing(proc_id=pid, work_id="w", payload="noop",
                      params={"priority": priority, "queue": queue},
                      input_files=list(files))


# ------------------------------------------------------ RollingPercentile

def test_rolling_percentile_tracks_full_sort_through_eviction():
    win = RollingPercentile(window=8)
    assert win.percentile(95) is None and win.median() is None
    vals = [5.0, 1.0, 9.0, 3.0, 7.0, 2.0, 8.0, 4.0, 6.0, 0.5, 9.5]
    for i, v in enumerate(vals):
        win.observe(v)
        expect = sorted(vals[max(0, i - 7):i + 1])
        # the bisect-maintained snapshot equals a full re-sort at every
        # step, including after window eviction kicks in
        assert win._sorted == expect
        assert win.median() == expect[len(expect) // 2]
        n = len(expect)
        assert win.percentile(95) == expect[min(n - 1, int(0.95 * n))]
    assert len(win) == 8
    assert win.values() == vals[-8:]  # arrival order preserved


def test_rolling_percentile_duplicate_values():
    win = RollingPercentile(window=4)
    for v in (1.0, 1.0, 1.0, 2.0, 1.0, 1.0):
        win.observe(v)
    assert win._sorted == sorted(win.values())
    assert len(win) == 4


# ------------------------------------------------------------ HistoryBook

def test_history_book_ewma_and_completion_rate():
    hb = HistoryBook(alpha=0.5)
    assert hb.completion_rate("q") == 0.5  # neutral prior, no division
    assert hb.ewma_latency("q") is None
    hb.record_job("q", 1.0)
    assert hb.ewma_latency("q") == 1.0  # first sample initializes
    hb.record_job("q", 3.0)
    assert hb.ewma_latency("q") == pytest.approx(2.0)
    hb.record_job("q", None, ok=False)  # expiry: outcome, no duration
    assert hb.samples("q") == 3
    # Laplace smoothed: (2 ok + 1) / (3 + 2)
    assert hb.completion_rate("q") == pytest.approx(3.0 / 5.0)


def test_history_book_staging_p95_needs_min_samples():
    hb = HistoryBook(min_staging_samples=4)
    for v in (0.01, 0.02, 0.03):
        hb.record_staging("tape", v)
    assert hb.staging_p95("tape") is None  # below the floor
    hb.record_staging("tape", 0.5)
    assert hb.staging_p95("tape") == 0.5
    assert hb.staging_p95("other") is None


def test_history_book_flush_load_roundtrip():
    hb = HistoryBook()
    hb.record_job("gpu", 2.0)
    hb.record_job("gpu", 4.0, ok=False)
    hb.record_staging("tape", 0.1)
    rows = hb.flush_dirty()
    assert [r["key"] for r in rows] == ["gpu"]
    assert rows[0]["scope"] == "queue"
    assert hb.flush_dirty() == []  # dirty set cleared
    warm = HistoryBook()
    assert warm.load(rows) == 1
    assert warm.completion_rate("gpu") == hb.completion_rate("gpu")
    assert warm.ewma_latency("gpu") == hb.ewma_latency("gpu")
    # staging windows are deliberately NOT journaled (stale on restart)
    assert warm.staging_p95("tape") is None


# ---------------------------------------------------------- AffinityIndex

def test_affinity_index_scores_ttl_and_prune():
    idx = AffinityIndex(ttl=10.0)
    idx.update("w1", ["a", "b", "c"], now=0.0)
    assert idx.score("w1", ["a", "c", "z"], now=1.0) == 2
    assert idx.score("w2", ["a"], now=1.0) == 0  # unknown worker
    # replace, not merge: a fresh manifest drops evicted entries
    idx.update("w1", ["d"], now=2.0)
    assert idx.score("w1", ["a"], now=2.0) == 0
    assert idx.score("w1", ["d"], now=2.0) == 1
    # expiry: a manifest older than ttl stops attracting jobs
    assert idx.score("w1", ["d"], now=13.0) == 0
    assert idx.prune(now=13.0) == 1
    assert idx.snapshot() == {}


def test_intel_plane_rescore_boost_thresholds():
    plane = IntelPlane(min_rescore_samples=4)
    assert plane.rescore_boost("q") == 0  # no history yet
    for _ in range(4):
        plane.history.record_job("bad", None, ok=False)
        plane.history.record_job("good", 0.1, ok=True)
    assert plane.rescore_boost("bad") == -1
    assert plane.rescore_boost("good") == 0  # 5/6 < 0.95
    for _ in range(40):
        plane.history.record_job("good", 0.1, ok=True)
    assert plane.rescore_boost("good") == 1
    assert plane.affinity_hit_rate() is None  # no leases scored yet


# ----------------------------------------------------- stats table (store)

@pytest.mark.parametrize("kind", ["memory", "sqlite", "buffered"])
def test_stats_table_roundtrip(kind, tmp_path):
    if kind == "memory":
        store = InMemoryStore()
    elif kind == "sqlite":
        store = SqliteStore(str(tmp_path / "stats.db"))
    else:
        store = BufferedStore(SqliteStore(str(tmp_path / "stats.db")),
                              flush_interval_ms=10_000)
    rows = [{"scope": "queue", "key": "gpu",
             "data": {"ewma_s": 1.5, "completed": 3, "failed": 1},
             "updated_at": 111.0}]
    store.save_stats(rows)
    # upsert: same (scope, key) overwrites, different key adds
    store.save_stats([{"scope": "queue", "key": "gpu",
                       "data": {"ewma_s": 2.0, "completed": 4,
                                "failed": 1}, "updated_at": 222.0},
                      {"scope": "queue", "key": "cpu",
                       "data": {"ewma_s": 0.1, "completed": 1,
                                "failed": 0}, "updated_at": 222.0}])
    loaded = {r["key"]: r for r in store.load_stats(scope="queue")}
    assert set(loaded) == {"gpu", "cpu"}
    assert loaded["gpu"]["data"]["ewma_s"] == 2.0
    assert loaded["gpu"]["updated_at"] == 222.0
    assert store.load_stats(scope="nope") == []
    assert len(store.load_stats()) == 2
    store.close()


def test_stats_rows_flow_through_journal_op(tmp_path):
    """The 'stats' op kind dispatches through save_many on both
    backends — the Watchdog journals history in one batched commit."""
    rows = [{"scope": "queue", "key": "q1",
             "data": {"completed": 7}, "updated_at": 1.0}]
    for store in (InMemoryStore(),
                  SqliteStore(str(tmp_path / "ops.db"))):
        store.save_many([("stats", rows)])
        assert store.load_stats(scope="queue")[0]["data"][
            "completed"] == 7
        store.close()


# ---------------------------------------- scheduler surface + warm start

def test_queue_stats_reports_boost_and_rate():
    s = JobScheduler(default_ttl=30.0)
    s.attach(InMemoryStore())
    plane = s.enable_intel(IntelPlane(min_rescore_samples=2))
    s.enqueue(_proc("p1", queue="gpu", priority=3))
    s.enqueue(_proc("p2", queue="gpu"))
    for _ in range(40):  # (40+1)/(40+2) ≈ 0.976 >= the 0.95 bar
        plane.history.record_job("gpu", 0.1, ok=True)
    assert s.rescore_queue_priorities() == {"gpu": 1}
    assert s.rescore_queue_priorities() == {}  # stable: no re-change
    qs = s.queue_stats()
    assert qs["gpu"]["pending"] == 2
    assert qs["gpu"]["boost"] == 1
    assert qs["gpu"]["base_priority"] == 3
    assert qs["gpu"]["effective_priority"] >= 4  # base + boost
    assert qs["gpu"]["completion_rate"] == round(41.0 / 42.0, 4)


def test_distributed_wfm_warm_starts_history_from_store():
    store = InMemoryStore()
    store.save_stats([{"scope": "queue", "key": "tape",
                       "data": {"ewma_s": 2.5, "completed": 30,
                                "failed": 2}, "updated_at": 1.0}])
    idds = IDDS(executor=DistributedWFM(intel=True), store=store)
    try:
        intel = idds.scheduler.intel
        assert intel is not None
        assert intel.history.ewma_latency("tape") == 2.5
        assert intel.history.samples("tape") == 32
    finally:
        idds.close()


# ------------------------------------------------- Conductor hedge pass

def test_conductor_hedges_against_learned_p95():
    cold = ColdStore(drives=2)
    cold.add(TapeFile("straggler", size=1, payload=b"x"))
    ddm = CarouselDDM(cold, DiskCache(10_000))
    idds = IDDS(executor=DistributedWFM(intel=True), ddm=ddm)
    try:
        intel = idds.scheduler.intel
        cond = next(d for d in idds.daemons
                    if isinstance(d, Conductor))
        st = Stager(cold, ddm.cache, workers=1)
        ddm.attach_stager("tape", st)
        # learned history: staging normally lands in ~10ms
        for _ in range(10):
            intel.history.record_staging("tape", 0.01)
        # a straggler submitted 'long ago' and still in flight
        st.records["straggler"] = StageRecord(
            "straggler", time.monotonic() - 1.0)
        # landed latencies drain into the HistoryBook on the same pass
        st._recent_latencies.append(("f0", 0.02))
        hedged = cond._hedge_pass()
        assert hedged == 1
        assert intel.hedges_issued == 1
        assert st.records["straggler"].hedged
        assert intel.history.snapshot()["staging"]["tape"][
            "samples"] == 11  # the drained landing was recorded
        # a record hedges at most once: repeated passes converge
        assert cond._hedge_pass() == 0
        st.shutdown()
    finally:
        idds.close()


# --------------------------------------------- Watchdog housekeeping

def test_watchdog_housekeeping_journals_and_rescores():
    store = InMemoryStore()
    idds = IDDS(executor=DistributedWFM(intel=True), store=store)
    try:
        sched = idds.scheduler
        intel = sched.intel
        intel.min_rescore_samples = 3
        for i in range(4):
            sched.enqueue(_proc(f"p{i}", queue="flaky"))
            job = sched.lease("w1", queues=["flaky"])
            sched.complete(job["job_id"], "w1", error="boom")
        wd = next(d for d in idds.daemons if isinstance(d, Watchdog))
        wd._intel_housekeeping()
        # adaptive reprioritization: a failing queue is deprioritized
        assert sched.queue_stats() == {} or True  # queue drained
        assert sched._queue_boost.get("flaky") == -1
        assert intel.rescores == 1
        # the learned history was persisted for the next head
        rows = store.load_stats(scope="queue")
        assert [r["key"] for r in rows] == ["flaky"]
        assert rows[0]["data"]["failed"] == 4
        # housekeeping flushed the dirty set: nothing re-journaled
        assert intel.history.flush_dirty() == []
    finally:
        idds.close()


# ------------------------------------------------------- REST surface

def test_rest_intel_and_queues_endpoints():
    with RestGateway(IDDS(executor=DistributedWFM(
            lease_ttl=30.0, intel=True))) as gw:
        client = IDDSClient(gw.url)
        sched = gw.idds.scheduler
        sched.enqueue(_proc("p1", queue="tape",
                            files=["ds1/f1", "ds1/f2"]))
        sched.enqueue(_proc("p2", queue="tape",
                            files=["ds2/f1"]))
        # manifest rides the lease body: affinity routes p2 first
        job = client.lease_job("w1", manifest=["ds2/f1"])
        assert job["job_id"] == "p2"
        # manifest also refreshes over heartbeat
        client.heartbeat_job(job["job_id"], "w1",
                             manifest=["ds2/f1", "out/o1"])
        client.complete_job(job["job_id"], "w1", result={})
        snap = client.intel()
        assert snap["enabled"] is True
        assert snap["affinity"]["workers"] == {"w1": 2}
        assert snap["affinity"]["hits"] == 1
        assert snap["history"]["queues"]["tape"]["completed"] == 1
        qs = client.queues()
        assert qs["distributed"] is True and qs["intel"] is True
        assert qs["queues"]["tape"]["pending"] == 1
        assert qs["queues"]["tape"]["completion_rate"] is not None


def test_rest_intel_disabled_and_bad_manifest():
    with RestGateway(IDDS(executor=DistributedWFM(
            lease_ttl=30.0))) as gw:
        client = IDDSClient(gw.url)
        snap = client.intel()
        assert snap == {"enabled": False, "distributed": True}
        qs = client.queues()
        assert qs["intel"] is False
        # malformed manifest is a 400, not a crash
        from repro.core.client import IDDSClientError
        with pytest.raises(IDDSClientError) as ei:
            client._post("/v1/jobs/lease",
                         {"worker_id": "w1", "manifest": "not-a-list"},
                         idempotent=True)
        assert ei.value.status == 400


def test_rest_intel_on_inline_head():
    """A non-distributed head answers /v1/intel and /v1/queues with
    benign envelopes instead of the NotDistributed 400."""
    with RestGateway(IDDS()) as gw:
        client = IDDSClient(gw.url)
        assert client.intel() == {"enabled": False,
                                  "distributed": False}
        assert client.queues() == {"queues": {}, "distributed": False}
