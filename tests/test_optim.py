"""Optimizer + schedule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, global_norm)


def test_global_norm_and_clip():
    tree = {"a": jnp.full((3,), 4.0), "b": jnp.full((4,), 3.0)}
    n = float(global_norm(tree))
    np.testing.assert_allclose(n, np.sqrt(3 * 16 + 4 * 9), rtol=1e-6)
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # under the limit: unchanged
    same, _ = clip_by_global_norm(tree, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 4.0)


def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, lr=0.05,
                                        weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_weight_decay_skips_1d():
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    state = adamw_init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = adamw_update(params, zeros, state, lr=0.1, weight_decay=0.5)
    assert float(p2["w"][0, 0]) < 1.0   # decayed
    np.testing.assert_allclose(np.asarray(p2["b"]), 1.0)  # not decayed


def test_bf16_moment_compression():
    params = {"w": jnp.ones((4, 4))}
    state = adamw_init(params, dtype=jnp.bfloat16)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4, 4), 0.1)}
    p2, s2, _ = adamw_update(params, g, state, lr=0.01)
    assert s2["m"]["w"].dtype == jnp.bfloat16
    assert not np.array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(0, base_lr=1.0, warmup_steps=10,
                                total_steps=100))
    lr_w = float(cosine_schedule(10, base_lr=1.0, warmup_steps=10,
                                 total_steps=100))
    lr_end = float(cosine_schedule(100, base_lr=1.0, warmup_steps=10,
                                   total_steps=100))
    assert lr0 == 0.0
    np.testing.assert_allclose(lr_w, 1.0, rtol=1e-6)
    np.testing.assert_allclose(lr_end, 0.1, rtol=1e-5)  # min_ratio
    # monotone warmup
    ws = [float(cosine_schedule(s, base_lr=1.0, warmup_steps=10,
                                total_steps=100)) for s in range(11)]
    assert ws == sorted(ws)
