"""Daemon pipeline end-to-end (paper Fig. 1) + retries + incremental
fine-grained dispatch (the carousel mechanism at the Work level)."""
import pytest

from repro.core import messaging as M
from repro.core import payloads as reg
from repro.core.ddm import InMemoryDDM
from repro.core.idds import IDDS, AuthError
from repro.core.requests import Request
from repro.core.workflow import (Branch, Condition, FileRef, Workflow,
                                 WorkTemplate)


@pytest.fixture(autouse=True)
def _payloads():
    reg.register_payload("d_echo", lambda params, inputs: {
        "params": dict(params), "inputs": list(inputs)})
    yield


def test_end_to_end_chain():
    wf = Workflow(name="chain")
    wf.add_template(WorkTemplate(name="a", payload="d_echo"))
    wf.add_template(WorkTemplate(name="b", payload="d_echo"))
    wf.add_condition(Condition(trigger="a", true_next=[Branch("b")]))
    wf.add_initial("a", {"k": 1})
    idds = IDDS()
    rid = idds.submit(Request(workflow=wf).to_json())
    idds.pump()
    info = idds.request_status(rid)
    assert info["status"] == "finished"
    assert info["works"] == {"finished": 2}
    assert idds.stats["notifications"] == 2  # Conductor notified per output


def test_auth():
    wf = Workflow(name="auth")
    wf.add_template(WorkTemplate(name="a", payload="d_echo"))
    wf.add_initial("a", {})
    idds = IDDS(tokens={"sekrit"})
    with pytest.raises(AuthError):
        idds.submit(Request(workflow=wf, token="wrong").to_json())
    rid = idds.submit(Request(workflow=wf, token="sekrit").to_json())
    idds.pump()
    assert idds.request_status(rid)["status"] == "finished"


def test_carrier_retries_to_success():
    calls = {"n": 0}

    def flaky(params, inputs):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("boom")
        return {"ok": True}

    reg.register_payload("d_flaky", flaky)
    wf = Workflow(name="flaky")
    wf.add_template(WorkTemplate(name="f", payload="d_flaky",
                                 max_attempts=5))
    wf.add_initial("f", {})
    idds = IDDS()
    rid = idds.submit_workflow(wf)
    idds.pump()
    assert idds.stats["job_attempts"] == 3
    assert idds.stats["job_retries"] == 2
    assert idds.request_status(rid)["works"] == {"finished": 1}


def test_carrier_exhausts_attempts_subfinished():
    reg.register_payload("d_alwaysfail",
                         lambda p, i: (_ for _ in ()).throw(
                             RuntimeError("nope")))
    wf = Workflow(name="fail")
    wf.add_template(WorkTemplate(name="f", payload="d_alwaysfail",
                                 max_attempts=2))
    wf.add_initial("f", {})
    idds = IDDS()
    rid = idds.submit_workflow(wf)
    idds.pump()
    assert idds.stats["job_attempts"] == 2
    assert idds.stats["processings_failed"] == 1
    assert idds.request_status(rid)["works"] == {"subfinished": 1}


def test_fine_granularity_incremental_dispatch():
    """Files become available one at a time; fine-granularity Works get one
    Processing per file, created as availability messages land."""
    ddm = InMemoryDDM()
    files = [FileRef(f"f{i}", size=10, available=False) for i in range(4)]
    ddm.register_collection("coll-in", files)
    idds = IDDS(ddm=ddm)

    wf = Workflow(name="fine")
    wf.add_template(WorkTemplate(name="w", payload="d_echo",
                                 input_collection="coll-in",
                                 granularity="fine"))
    wf.add_initial("w", {})
    rid = idds.submit_workflow(wf)
    idds.pump()
    # nothing available yet: work activated, no processings
    assert idds.stats.get("processings_created", 0) == 0

    for i in range(4):
        ddm.set_available("coll-in", f"f{i}")
        idds.ctx.bus.publish(M.T_COLLECTION_UPDATED,
                             {"collection": "coll-in", "file": f"f{i}"})
        idds.pump()
        assert idds.stats["processings_created"] == i + 1

    info = idds.request_status(rid)
    assert info["works"] == {"finished": 1}
    # each file processed exactly once, input marked processed in DDM
    coll = ddm.get_collection("coll-in")
    assert coll.n_processed == 4


def test_coarse_granularity_waits_for_all():
    ddm = InMemoryDDM()
    files = [FileRef(f"g{i}", size=1, available=i == 0) for i in range(3)]
    ddm.register_collection("coll-c", files)
    idds = IDDS(ddm=ddm)
    wf = Workflow(name="coarse")
    wf.add_template(WorkTemplate(name="w", payload="d_echo",
                                 input_collection="coll-c",
                                 granularity="coarse"))
    wf.add_initial("w", {})
    rid = idds.submit_workflow(wf)
    idds.pump()
    assert idds.stats.get("processings_created", 0) == 0  # still waiting
    for i in (1, 2):
        ddm.set_available("coll-c", f"g{i}")
    idds.ctx.bus.publish(M.T_COLLECTION_UPDATED, {"collection": "coll-c"})
    idds.pump()
    assert idds.stats["processings_created"] == 1  # one big Processing
    procs = list(idds.ctx.processings.values())
    assert sorted(procs[0].input_files) == ["g0", "g1", "g2"]
    assert idds.request_status(rid)["works"] == {"finished": 1}


def test_threaded_mode():
    import time
    reg.register_payload("d_sleep",
                         lambda p, i: (time.sleep(0.005), {"i": p["i"]})[1])
    wf = Workflow(name="thr")
    wf.add_template(WorkTemplate(name="t", payload="d_sleep"))
    for i in range(12):
        wf.add_initial("t", {"i": i})
    idds = IDDS(sync=False, max_workers=6)
    idds.start()
    try:
        rid = idds.submit_workflow(wf)
        info = idds.wait_request(rid, timeout=30)
        assert info["works"] == {"finished": 12}
    finally:
        idds.stop()


def test_request_json_round_trip():
    wf = Workflow(name="rt")
    wf.add_template(WorkTemplate(name="a", payload="d_echo"))
    wf.add_initial("a", {"p": 3})
    req = Request(workflow=wf, requester="alice", token="tok")
    j = req.to_json()
    req2 = Request.from_json(j)
    assert req2.request_id == req.request_id
    assert req2.requester == "alice"
    assert req2.workflow.to_json() == wf.to_json()
